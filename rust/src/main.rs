//! `dymoe` — the L3 coordinator CLI.
//!
//! ```text
//! dymoe info        --model mixtral-mini
//! dymoe serve       --model mixtral-mini --vram 16 --requests 10 [--strategy dymoe-40]
//! dymoe serve-fleet --model mixtral-mini --vram 16 --requests 24 --rate 0.25 \
//!                   [--arrival poisson|bursty|ramp] [--scenario mixed-flash:0.5] \
//!                   [--batch-slo-scale 8] [--sessions 8] [--sched fifo|rr|slo] \
//!                   [--max-decode-batch 8] [--replicas 4] \
//!                   [--dispatch rr|jsq|affinity|predictive] [--probe-depth 4] \
//!                   [--replica-hw 24 --replica-hw 12:8:10:5] [--fail 30@0] [--drain 45@1] \
//!                   [--parallel 4] [--host-pool 2:shared]
//! dymoe experiment  <fig1|...|table3|all> [--items N] [--requests N] [--models a,b]
//! dymoe timeline    --model mixtral-mini --vram 16
//! ```
//!
//! (Arg parsing is hand-rolled: clap is not vendored in this offline
//! build — see Cargo.toml.)

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use dymoe::baselines::{
    AccelerateStatic, Fiddler, LoadOnDemand, MixtralOffloading, MoeInfinity, Uniform,
};
use dymoe::config::{
    ChurnEvent, ChurnKind, HardwareConfig, HostPoolConfig, LowMode, PolicyConfig,
    ServingConfig, SystemConfig,
};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::coordinator::strategy::{DyMoEStrategy, Strategy};
use dymoe::experiments::{self, ExpOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::model::executor::Executor;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess};
use dymoe::serving::metrics::SloTargets;
use dymoe::serving::policy::{DispatchKind, PolicyKind};
use dymoe::serving::{run_cluster, FleetConfig, Scenario};
use dymoe::util::json::Json;
use dymoe::util::table::{fmt_secs, Table};
use dymoe::workload::TraceGen;

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    /// Every flag occurrence in order (repeatable flags like
    /// `--replica-hw`; `flags` keeps last-one-wins for the rest).
    repeated: Vec<(String, String)>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut repeated = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            let value = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 2;
                argv[i - 1].clone()
            } else {
                i += 1;
                "true".to_string()
            };
            flags.insert(name.to_string(), value.clone());
            repeated.push((name.to_string(), value));
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags, repeated }
}

impl Args {
    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        self.flags
            .get(name)
            .map(|v| v.parse().map_err(|_| anyhow!("--{name} wants a number")))
            .unwrap_or(Ok(default))
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    fn get_all(&self, name: &str) -> Vec<String> {
        self.repeated
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, v)| v.clone())
            .collect()
    }
}

fn make_strategy(
    name: &str,
    m: &dymoe::model::manifest::MiniModel,
    retention: f64,
) -> Result<Box<dyn Strategy>> {
    Ok(match name {
        "dymoe-40" | "dymoe" => Box::new(DyMoEStrategy::new(PolicyConfig {
            retention,
            low_mode: LowMode::Skip,
            ..Default::default()
        })),
        "dymoe-42" => Box::new(DyMoEStrategy::new(PolicyConfig {
            retention,
            low_mode: LowMode::Int2,
            ..Default::default()
        })),
        "lod" => Box::new(LoadOnDemand::new(Precision::Int4)),
        "uniform-int4" => Box::new(Uniform::new(Precision::Int4)),
        "uniform-bf16" => Box::new(Uniform::new(Precision::Bf16)),
        "accelerate" => Box::new(AccelerateStatic::new(Precision::Int4)),
        "mixtral-offloading" => Box::new(MixtralOffloading::new(Precision::Int4, m.top_k)),
        "moe-infinity" => {
            Box::new(MoeInfinity::new(Precision::Int4, m.n_layers, m.n_experts, m.top_k))
        }
        "fiddler" => Box::new(Fiddler),
        _ => bail!(
            "unknown strategy {name:?}; try dymoe-40, dymoe-42, lod, uniform-int4, \
             uniform-bf16, accelerate, mixtral-offloading, moe-infinity, fiddler"
        ),
    })
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let model = args.get("model", "mixtral-mini");
    let assets = ModelAssets::load(&artifacts, &model)?;
    let m = &assets.manifest.model;
    println!("model        : {}", m.name);
    println!("layers       : {}", m.n_layers);
    println!("d_model/ffn  : {}/{}", m.d_model, m.d_ffn);
    println!("experts      : {} (top-{})", m.n_experts, m.top_k);
    println!("vocab/seq    : {}/{}", m.vocab, m.max_seq);
    println!("artifacts    : {}", assets.manifest.artifacts.len());
    println!("weight secs  : {}", assets.manifest.sections.len());
    for p in Precision::ALL_STORED {
        println!(
            "expert bytes : {:>5} = {}",
            p.tag(),
            assets.manifest.expert_transfer_bytes(p)
        );
    }
    let paper = dymoe::config::PaperModel::for_mini(&m.name)?;
    println!("paper scale  : {} ({} layers x {} experts)", paper.name, paper.n_layers, paper.n_experts);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let model = args.get("model", "mixtral-mini");
    let vram: u64 = args.get_usize("vram", 16)? as u64;
    let requests = args.get_usize("requests", 10)?;
    let retention: f64 = args
        .get("retention", "0.75")
        .parse()
        .map_err(|_| anyhow!("--retention wants a float"))?;
    let strat_name = args.get("strategy", "dymoe-40");
    let seed = args.get_usize("seed", 11)? as u64;

    let assets = Arc::new(ModelAssets::load(&artifacts, &model)?);
    let m = assets.manifest.model.clone();
    let strategy = make_strategy(&strat_name, &m, retention)?;
    let sys = SystemConfig::edge_preset(&model, vram)?;
    println!(
        "serving {model} as {} @ {vram} GB VRAM (paper-scale {})",
        strategy.name(),
        sys.paper.name
    );
    let mut engine = Engine::new(&assets, sys, strategy)?;
    let mut gen = TraceGen::new(seed, m.max_seq.min(80), (m.max_cache - m.max_seq).min(16));
    let mut report = dymoe::metrics::LatencyReport::default();
    for i in 0..requests {
        let r = gen.next_request();
        let out = engine.run(&r.prompt, r.max_new)?;
        report.record(out.ttft, out.tpot());
        println!(
            "req {i:>3}: prompt={:>3} tokens out={:>3}  TTFT={}  TPOT={}",
            r.prompt.len(),
            out.tokens.len(),
            fmt_secs(out.ttft),
            fmt_secs(out.tpot()),
        );
    }
    let mut t = Table::new(
        "latency summary",
        &["strategy", "TTFT mean", "TTFT p95", "TPOT mean", "TPOT p95"],
    );
    t.row(report.summary_row(&engine.strategy.name()));
    println!("\n{}", t.render());
    println!(
        "cache: {} hits / {} misses (hit rate {:.2}), {} promotions, {} reuses, \
         {} evictions, {} replacements",
        engine.cache.stats.hits,
        engine.cache.stats.misses,
        engine.cache.stats.hit_rate(),
        engine.cache.stats.promotions,
        engine.cache.stats.conservative_reuses,
        engine.cache.stats.evictions,
        engine.cache.stats.replacements
    );
    println!(
        "prefetch: {} issued, {} useful ({:.2} accuracy); transferred {:.2} GB; \
         {} expert execs ({} skipped, {} on CPU)",
        engine.prefetch_stats.issued,
        engine.prefetch_stats.useful,
        engine.prefetch_stats.accuracy(),
        engine.stats.transferred_bytes as f64 / 1e9,
        engine.stats.expert_execs,
        engine.stats.skipped_experts,
        engine.stats.cpu_execs,
    );
    Ok(())
}

/// `serve-fleet`: open-loop multi-session serving across a cluster of
/// DyMoE replicas with fleet SLO metrics (`--replicas 1`, the default,
/// is the classic single-device fleet, tick for tick).
fn cmd_serve_fleet(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let model = args.get("model", "mixtral-mini");
    let vram: u64 = args.get_usize("vram", 16)? as u64;
    let requests = args.get_usize("requests", 24)?;
    let retention: f64 = args
        .get("retention", "0.75")
        .parse()
        .map_err(|_| anyhow!("--retention wants a float"))?;
    let strat_name = args.get("strategy", "dymoe-40");
    let seed = args.get_usize("seed", 11)? as u64;
    let rate: f64 = args
        .get("rate", "0.25")
        .parse()
        .map_err(|_| anyhow!("--rate wants a float (requests / virtual second)"))?;
    // `--scenario` composes per-class arrival processes itself and is
    // therefore mutually exclusive with a hand-picked `--arrival`.
    let scenario_spec = match args.get("scenario", "").as_str() {
        "" => None,
        "true" => bail!(
            "--scenario wants NAME[:ARGS] (steady, diurnal, flash-crowd, mixed, \
             mixed-diurnal, mixed-flash)"
        ),
        spec => Some(spec.to_string()),
    };
    if scenario_spec.is_some() && args.flags.contains_key("arrival") {
        bail!("--scenario and --arrival are mutually exclusive (the scenario picks the processes)");
    }
    let process = ArrivalProcess::from_cli(&args.get("arrival", "poisson"), rate)?;
    let policy = PolicyKind::parse(&args.get("sched", "slo"))?;
    let dispatch = DispatchKind::parse(&args.get("dispatch", "rr"))?;
    let replicas = args.get_usize("replicas", 1)?.max(1);
    let max_sessions = args.get_usize("sessions", 8)?;
    // Worker threads for inter-boundary replica ticking; outcomes are
    // bit-identical to serial (--parallel 1), so this is purely a
    // wall-clock knob.
    let parallel = args.get_usize("parallel", 1)?.max(1);
    // Churn schedule: repeatable `--fail T@R` / `--drain T@R` events,
    // fired by the cluster in virtual-time order between ticks.
    let mut churn = Vec::new();
    for spec in args.get_all("fail") {
        churn.push(ChurnEvent::parse_spec(ChurnKind::Fail, &spec)?);
    }
    for spec in args.get_all("drain") {
        churn.push(ChurnEvent::parse_spec(ChurnKind::Drain, &spec)?);
    }
    for e in &churn {
        if e.replica >= replicas {
            bail!(
                "--{} {}@{} targets a replica outside the cluster (have --replicas {replicas})",
                e.kind.name(),
                e.at,
                e.replica
            );
        }
    }
    // Shared host expert tier under the per-replica VRAM caches; absent
    // (the default) keeps every code path bitwise-identical to before.
    let host_pool = match args.get("host-pool", "").as_str() {
        "" => None,
        "true" => bail!("--host-pool wants CAP_GB[:static|shared|pinned]"),
        spec => Some(HostPoolConfig::parse_spec(spec)?),
    };
    let serving = ServingConfig {
        max_sessions,
        ttft_slo_s: args
            .get("ttft-slo", "5.0")
            .parse()
            .map_err(|_| anyhow!("--ttft-slo wants seconds"))?,
        tpot_slo_s: args
            .get("tpot-slo", "0.5")
            .parse()
            .map_err(|_| anyhow!("--tpot-slo wants seconds"))?,
        // Cross-session batched decode: default to batching as wide as
        // the admission limit; 1 restores serial interleaved decode.
        max_decode_batch: args.get_usize("max-decode-batch", max_sessions.max(1))?,
        // Chunked prefill: 0 (default) keeps monolithic prefill — the
        // pre-chunking fleet path, step for step; a positive budget
        // fuses that many prompt tokens per tick with the decode batch.
        chunk_tokens: args.get_usize("chunk-tokens", 0)?,
        replicas,
        churn,
        parallel,
        host_pool,
        // Gate-probe width for --dispatch predictive; 0 (the default)
        // tracks the model's top_k.  Ignored by every other policy.
        probe_depth: args.get_usize("probe-depth", 0)?,
        // Batch-class SLO relaxation for --scenario runs; --arrival
        // traces carry no per-request SLO, so this is inert there.
        batch_slo_scale: args
            .get("batch-slo-scale", "8.0")
            .parse()
            .map_err(|_| anyhow!("--batch-slo-scale wants a factor >= 1"))?,
    };
    let scenario = scenario_spec
        .as_deref()
        .map(|spec| {
            Scenario::from_cli(
                spec,
                rate,
                SloTargets { ttft_s: serving.ttft_slo_s, tpot_s: serving.tpot_slo_s },
                serving.batch_slo_scale,
            )
        })
        .transpose()?;
    // Heterogeneous replicas: each `--replica-hw
    // VRAM[:PCIE[:TFLOPS[:HOSTGBPS]]]` occurrence defines one hardware
    // class; specs cycle over the replica count (two specs x four
    // replicas = a big.LITTLE pair of pairs).  Without the flag every
    // replica runs the `--vram` preset.
    let hw_specs = args.get_all("replica-hw");
    if hw_specs.len() > replicas {
        bail!(
            "{} --replica-hw specs for {replicas} replica(s); raise --replicas or drop specs",
            hw_specs.len()
        );
    }
    // Trace export: timeline recording turns on (for every replica
    // engine) only when a trace is requested, so the absent-flag fast
    // path keeps the zero-overhead `record: false` behaviour.
    let trace_out = match args.get("trace-out", "").as_str() {
        "" => None,
        "true" => bail!("--trace-out wants a file path"),
        p => Some(p.to_string()),
    };

    let assets = Arc::new(ModelAssets::load(&artifacts, &model)?);
    let m = assets.manifest.model.clone();
    let sys = SystemConfig::edge_preset(&model, vram)?;
    let traffic = match &scenario {
        Some(s) => format!(
            "scenario {} with {} tenant class(es), batch SLO x{}",
            s.name,
            s.classes.len(),
            serving.batch_slo_scale
        ),
        None => format!("{process:?}"),
    };
    println!(
        "fleet-serving {model} as {strat_name} on {replicas} replica(s) ({} dispatch): \
         {requests} arrivals ({traffic}), per replica <= {} sessions, decode batch <= {}, \
         {}, {} scheduling, SLO ttft {:.2}s / tpot {:.3}s",
        dispatch.name(),
        serving.max_sessions,
        serving.max_decode_batch.max(1),
        if serving.chunk_tokens == 0 {
            "monolithic prefill".to_string()
        } else {
            format!("chunked prefill <= {} tok/tick", serving.chunk_tokens)
        },
        policy.name(),
        serving.ttft_slo_s,
        serving.tpot_slo_s,
    );
    if !serving.churn.is_empty() {
        let sched: Vec<String> = serving
            .churn
            .iter()
            .map(|e| format!("{} {}@{}", e.kind.name(), e.at, e.replica))
            .collect();
        println!("churn schedule: {}", sched.join(", "));
    }
    if let Some(hp) = &serving.host_pool {
        println!(
            "host pool: {:.2} GB host tier ({} partitioning), host link {:.1} GB/s \
             shared by live replicas",
            hp.capacity_bytes as f64 / 1e9,
            hp.policy.name(),
            sys.hardware.host_link_gbps / 1e9,
        );
    }
    if parallel > 1 {
        println!("parallel ticking on {parallel} worker thread(s) (bit-identical to serial)");
    }

    // Serial runs share one compiled executor across replicas (weights
    // + artifacts are immutable, so this only saves compilation);
    // parallel runs need one executor per replica because the executor
    // holds thread-confined scratch state — run_cluster enforces this.
    let shared_exec = if parallel > 1 { None } else { Some(Rc::new(Executor::new(assets.clone())?)) };
    let mut engines = Vec::with_capacity(replicas);
    let mut hw_labels = Vec::with_capacity(replicas);
    for i in 0..replicas {
        let mut sys_i = sys.clone();
        let label = if hw_specs.is_empty() {
            format!("{vram}GB")
        } else {
            let spec = &hw_specs[i % hw_specs.len()];
            sys_i.hardware = HardwareConfig::parse_spec(spec)?;
            spec.clone()
        };
        let strategy = make_strategy(&strat_name, &m, retention)?;
        let exec_i = match &shared_exec {
            Some(e) => e.clone(),
            None => Rc::new(Executor::new(assets.clone())?),
        };
        engines.push(Engine::with_executor(
            &assets,
            sys_i,
            strategy,
            EngineOptions { record_timeline: trace_out.is_some(), ..Default::default() },
            exec_i,
        )?);
        hw_labels.push(label);
    }

    let mut content = TraceGen::new(seed, m.max_seq.min(80), (m.max_cache - m.max_seq).min(16));
    // Independent seeded streams for timing vs content (see serving::arrival).
    // A scenario composes per-class streams off the same timing seed, so
    // single-class scenarios reproduce the --arrival trace bit for bit.
    let trace = match &scenario {
        Some(s) => s.generate(seed ^ 0x5EED_CAFE, &mut content, requests)?,
        None => ArrivalGen::generate(seed ^ 0x5EED_CAFE, process, &mut content, requests)?,
    };
    let cfg = FleetConfig { serving, policy, dispatch };
    let cluster = run_cluster(&mut engines, trace, &cfg)?;
    let outcome = &cluster.fleet;

    for r in &outcome.per_request {
        println!(
            "req {:>3} [{:>11}]: arrived {:>8} queued {:>8}  TTFT={:>8}  TPOT={:>8}  \
             tokens={:>3}  {}{}{}",
            r.id,
            r.class.name(),
            fmt_secs(r.arrival),
            fmt_secs(r.queue_delay),
            fmt_secs(r.ttft),
            fmt_secs(r.tpot),
            r.tokens,
            if r.ttft_ok && r.tpot_ok { "ok" } else { "SLO-miss" },
            if r.retries > 0 {
                format!("  (re-dispatched x{})", r.retries)
            } else {
                String::new()
            },
            if r.preemptions > 0 {
                format!("  (preempted x{})", r.preemptions)
            } else {
                String::new()
            },
        );
    }
    println!();
    println!("{}", outcome.metrics.render(policy.name()));
    println!(
        "fleet: {} completed on {} replica(s), peak concurrency {}, {} scheduler steps, \
         makespan {}, load imbalance {:.2} (max/mean tokens per replica)",
        outcome.metrics.completed,
        replicas,
        outcome.peak_concurrency,
        outcome.steps,
        fmt_secs(outcome.metrics.makespan()),
        cluster.load_imbalance,
    );
    if cluster.churn.any() {
        println!(
            "churn: {} failed / {} drained replica(s); {} session(s) re-dispatched, \
             {} tokens of work lost, worst request re-dispatched x{}",
            cluster.churn.failed,
            cluster.churn.drained,
            cluster.churn.requeued,
            cluster.churn.lost_work_tokens,
            cluster.churn.max_retries,
        );
    }
    let preempted = outcome.metrics.preemptions();
    if preempted > 0 {
        println!(
            "preemption: {preempted} batch decode slot(s) preempted by urgent admissions \
             (sessions re-queued with work conserved)"
        );
    }
    println!(
        "batched decode: {} steps ({} tokens, mean batch {:.2}); expert reuse {:.2}x \
         ({} shared fetches saved vs serial)",
        outcome.dedup.decode_batches,
        outcome.dedup.decode_batch_tokens,
        outcome.dedup.mean_batch(),
        outcome.dedup.expert_reuse_ratio(),
        outcome.dedup.saved_fetches(),
    );
    println!(
        "chunked prefill: {} chunks ({} prompt tokens, mean chunk {:.2}), \
         {} mixed prefill+decode ticks; stall p99 {} (worst inter-token gap), \
         TTFT breakdown queue {} + prefill {}",
        outcome.phase.prefill_chunks,
        outcome.phase.prefill_chunk_tokens,
        outcome.phase.mean_chunk(),
        outcome.phase.mixed_steps,
        fmt_secs(outcome.metrics.stall.percentile(99.0)),
        fmt_secs(outcome.metrics.queue_delay.mean()),
        fmt_secs(outcome.metrics.prefill_time.mean()),
    );
    println!(
        "resources: gpu {:.0}% / pcie {:.0}% / cpu {:.0}% / nvme {:.0}% busy over \
         {replicas} replica(s) x makespan; peak session KV {:.1} MB",
        outcome.utilization.gpu * 100.0,
        outcome.utilization.pcie * 100.0,
        outcome.utilization.cpu * 100.0,
        outcome.utilization.nvme * 100.0,
        outcome.peak_kv_bytes as f64 / 1e6,
    );
    if cfg.serving.host_pool.is_some() {
        println!(
            "host pool: {} hits / {} SSD fills / {} upgrades (hit rate {:.2}), \
             {} evictions, staged {:.2} GB, host-link contention stall {:.3}s",
            cluster.pool.host_hits,
            cluster.pool.ssd_fills,
            cluster.pool.replacements,
            cluster.pool.hit_rate(),
            cluster.pool.evictions,
            cluster.pool.inserted_bytes as f64 / 1e9,
            cluster.pool.stall_s,
        );
        if cluster.pool.prestaged > 0 {
            println!(
                "pre-staging: {} staged, {} used, {} evicted unused (accuracy {:.2})",
                cluster.pool.prestaged,
                cluster.pool.prestage_used,
                cluster.pool.prestage_evicted,
                cluster.pool.prestage_accuracy(),
            );
        }
    }
    for (i, b) in cluster.replicas.iter().enumerate() {
        println!(
            "replica {i} [{}] ({}): {} dispatched, {} completed, goodput {:.3} r/s, \
             TTFT p99 {}, gpu {:.0}% / pcie {:.0}% / nvme {:.0}% busy",
            hw_labels[i],
            b.state.name(),
            b.dispatched,
            b.outcome.metrics.completed,
            b.outcome.metrics.goodput_rps(),
            fmt_secs(b.outcome.metrics.ttft.percentile(99.0)),
            b.outcome.utilization.gpu * 100.0,
            b.outcome.utilization.pcie * 100.0,
            b.outcome.utilization.nvme * 100.0,
        );
    }
    for (i, engine) in engines.iter().enumerate() {
        println!(
            "replica {i} cache: {} hits / {} misses (hit rate {:.2}), {} promotions, \
             {} reuses, {} evictions, {} replacements; prefetch {} issued, {} useful \
             ({:.2} accuracy); transferred {:.2} GB; {} expert execs ({} skipped, {} on CPU)",
            engine.cache.stats.hits,
            engine.cache.stats.misses,
            engine.cache.stats.hit_rate(),
            engine.cache.stats.promotions,
            engine.cache.stats.conservative_reuses,
            engine.cache.stats.evictions,
            engine.cache.stats.replacements,
            engine.prefetch_stats.issued,
            engine.prefetch_stats.useful,
            engine.prefetch_stats.accuracy(),
            engine.stats.transferred_bytes as f64 / 1e9,
            engine.stats.expert_execs,
            engine.stats.skipped_experts,
            engine.stats.cpu_execs,
        );
    }

    if args.flags.contains_key("json") {
        let path = match args.get("json", "").as_str() {
            "" | "true" => "FLEET_serving.json".to_string(),
            p => p.to_string(),
        };
        let j = fleet_json(
            &cluster,
            &hw_labels,
            policy,
            dispatch,
            scenario.as_ref().map(|s| s.name.as_str()),
        );
        std::fs::write(&path, j.to_string())?;
        println!("wrote {path}");
    }
    if let Some(path) = &trace_out {
        let doc = dymoe::trace::chrome::chrome_trace(&cluster);
        std::fs::write(path, doc.to_string())?;
        // Lint what we just wrote: a malformed trace should fail the
        // run loudly, not a Perfetto import three tools later.
        let rep = dymoe::trace::chrome::lint(&doc)?;
        println!(
            "wrote {path}: {} replica process(es), {} slices, {} counter samples, \
             {} instants, {} session events — open in https://ui.perfetto.dev \
             or chrome://tracing",
            rep.processes, rep.slices, rep.counters, rep.instants, rep.session_events
        );
    }
    Ok(())
}

/// Validate a Chrome-trace file (as produced by `serve-fleet
/// --trace-out`): JSON structure, per-track timestamp monotonicity,
/// non-negative durations, balanced session spans.  CI runs this over
/// the smoke run's artifact.
fn cmd_trace_lint(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: dymoe trace-lint <trace.json>"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let rep =
        dymoe::trace::chrome::lint(&doc).with_context(|| format!("linting {path}"))?;
    println!(
        "{path}: ok — {} replica process(es), {} slices, {} counter samples, \
         {} instants, {} session events",
        rep.processes, rep.slices, rep.counters, rep.instants, rep.session_events
    );
    Ok(())
}

/// Machine-readable `serve-fleet --json` summary: cluster-level SLO
/// metrics plus per-tenant-class and per-request breakdowns and
/// per-replica views with per-channel utilization.
fn fleet_json(
    cluster: &dymoe::serving::ClusterOutcome,
    hw_labels: &[String],
    policy: PolicyKind,
    dispatch: DispatchKind,
    scenario: Option<&str>,
) -> Json {
    let num = Json::Num;
    let metrics_obj = |o: &dymoe::serving::FleetOutcome| {
        let mut p = BTreeMap::new();
        p.insert("completed".to_string(), num(o.metrics.completed as f64));
        p.insert("ttft_p50_s".to_string(), num(o.metrics.ttft.percentile(50.0)));
        p.insert("ttft_p99_s".to_string(), num(o.metrics.ttft.percentile(99.0)));
        p.insert("tpot_p50_s".to_string(), num(o.metrics.tpot.percentile(50.0)));
        p.insert("tpot_p99_s".to_string(), num(o.metrics.tpot.percentile(99.0)));
        p.insert("queue_delay_mean_s".to_string(), num(o.metrics.queue_delay.mean()));
        p.insert("goodput_rps".to_string(), num(o.metrics.goodput_rps()));
        p.insert("throughput_tps".to_string(), num(o.metrics.throughput_tps()));
        p.insert("slo_attainment".to_string(), num(o.metrics.slo_attainment()));
        p.insert("makespan_s".to_string(), num(o.metrics.makespan()));
        p.insert("steps".to_string(), num(o.steps as f64));
        p.insert("expert_dedup_ratio".to_string(), num(o.dedup.expert_reuse_ratio()));
        p.insert("util_gpu".to_string(), num(o.utilization.gpu));
        p.insert("util_cpu".to_string(), num(o.utilization.cpu));
        p.insert("util_pcie".to_string(), num(o.utilization.pcie));
        p.insert("util_nvme".to_string(), num(o.utilization.nvme));
        Json::Obj(p)
    };
    let mut root = BTreeMap::new();
    root.insert("sched".to_string(), Json::Str(policy.name().to_string()));
    root.insert("dispatch".to_string(), Json::Str(dispatch.name().to_string()));
    if let Some(name) = scenario {
        root.insert("scenario".to_string(), Json::Str(name.to_string()));
    }
    root.insert("replicas".to_string(), num(cluster.replicas.len() as f64));
    root.insert("load_imbalance".to_string(), num(cluster.load_imbalance));
    let mut churn = BTreeMap::new();
    churn.insert("failed".to_string(), num(cluster.churn.failed as f64));
    churn.insert("drained".to_string(), num(cluster.churn.drained as f64));
    churn.insert("requeued".to_string(), num(cluster.churn.requeued as f64));
    churn.insert(
        "lost_work_tokens".to_string(),
        num(cluster.churn.lost_work_tokens as f64),
    );
    churn.insert("max_retries".to_string(), num(cluster.churn.max_retries as f64));
    root.insert("churn".to_string(), Json::Obj(churn));
    let mut pool = BTreeMap::new();
    pool.insert("host_hits".to_string(), num(cluster.pool.host_hits as f64));
    pool.insert("ssd_fills".to_string(), num(cluster.pool.ssd_fills as f64));
    pool.insert("hit_rate".to_string(), num(cluster.pool.hit_rate()));
    pool.insert("evictions".to_string(), num(cluster.pool.evictions as f64));
    pool.insert(
        "inserted_bytes".to_string(),
        num(cluster.pool.inserted_bytes as f64),
    );
    pool.insert("stall_s".to_string(), num(cluster.pool.stall_s));
    pool.insert("replacements".to_string(), num(cluster.pool.replacements as f64));
    pool.insert("prestaged".to_string(), num(cluster.pool.prestaged as f64));
    pool.insert("prestage_used".to_string(), num(cluster.pool.prestage_used as f64));
    pool.insert(
        "prestage_evicted".to_string(),
        num(cluster.pool.prestage_evicted as f64),
    );
    pool.insert("prestage_accuracy".to_string(), num(cluster.pool.prestage_accuracy()));
    root.insert("host_pool".to_string(), Json::Obj(pool));
    root.insert("cluster".to_string(), metrics_obj(&cluster.fleet));
    // Per-tenant-class SLO breakdown (interactive vs batch); one entry
    // per class that completed at least one request.
    let mut per_class = BTreeMap::new();
    for (class, cs) in &cluster.fleet.metrics.per_class {
        let mut c = BTreeMap::new();
        c.insert("completed".to_string(), num(cs.completed as f64));
        c.insert("ttft_p50_s".to_string(), num(cs.ttft.percentile(50.0)));
        c.insert("ttft_p99_s".to_string(), num(cs.ttft.percentile(99.0)));
        c.insert("tpot_p50_s".to_string(), num(cs.tpot.percentile(50.0)));
        c.insert("tpot_p99_s".to_string(), num(cs.tpot.percentile(99.0)));
        c.insert("queue_delay_mean_s".to_string(), num(cs.queue_delay.mean()));
        c.insert("slo_attainment".to_string(), num(cs.slo_attainment()));
        c.insert("tokens_total".to_string(), num(cs.tokens_total as f64));
        c.insert("preemptions".to_string(), num(cs.preemptions as f64));
        per_class.insert(class.name().to_string(), Json::Obj(c));
    }
    root.insert("per_class".to_string(), Json::Obj(per_class));
    // Per-request records (completion order) with the tenant class, so
    // downstream tooling can slice SLO behaviour without re-running.
    let per_request: Vec<Json> = cluster
        .fleet
        .per_request
        .iter()
        .map(|r| {
            let mut p = BTreeMap::new();
            p.insert("id".to_string(), num(r.id as f64));
            p.insert("class".to_string(), Json::Str(r.class.name().to_string()));
            p.insert("arrival_s".to_string(), num(r.arrival));
            p.insert("queue_delay_s".to_string(), num(r.queue_delay));
            p.insert("ttft_s".to_string(), num(r.ttft));
            p.insert("tpot_s".to_string(), num(r.tpot));
            p.insert("tokens".to_string(), num(r.tokens as f64));
            p.insert("slo_ok".to_string(), Json::Bool(r.ttft_ok && r.tpot_ok));
            p.insert("retries".to_string(), num(r.retries as f64));
            p.insert("preemptions".to_string(), num(r.preemptions as f64));
            Json::Obj(p)
        })
        .collect();
    root.insert("per_request".to_string(), Json::Arr(per_request));
    let per_replica: Vec<Json> = cluster
        .replicas
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let mut p = match metrics_obj(&b.outcome) {
                Json::Obj(p) => p,
                _ => unreachable!(),
            };
            p.insert("replica".to_string(), num(i as f64));
            p.insert("dispatched".to_string(), num(b.dispatched as f64));
            p.insert(
                "hw".to_string(),
                Json::Str(hw_labels.get(i).cloned().unwrap_or_default()),
            );
            p.insert("state".to_string(), Json::Str(b.state.name().to_string()));
            Json::Obj(p)
        })
        .collect();
    root.insert("per_replica".to_string(), Json::Arr(per_replica));
    Json::Obj(root)
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let model = args.get("model", "mixtral-mini");
    let vram: u64 = args.get_usize("vram", 16)? as u64;
    let strat_name = args.get("strategy", "dymoe-40");
    let assets = Arc::new(ModelAssets::load(&artifacts, &model)?);
    let m = assets.manifest.model.clone();
    let strategy = make_strategy(&strat_name, &m, 0.75)?;
    let sys = SystemConfig::edge_preset(&model, vram)?;
    let mut engine = Engine::with_options(
        &assets,
        sys,
        strategy,
        EngineOptions { record_timeline: true, ..Default::default() },
    )?;
    let prompt: Vec<i32> = (0..32).map(|i| 1 + (i * 7) % 60).collect();
    let out = engine.run(&prompt, 6)?;
    println!(
        "{} TTFT={} TPOT={}",
        engine.strategy.name(),
        fmt_secs(out.ttft),
        fmt_secs(out.tpot())
    );
    println!("{}", engine.timeline.render_ascii(100));
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: dymoe experiment <id|all>"))?
        .clone();
    let mut opts = ExpOptions {
        artifacts: args.get("artifacts", "artifacts"),
        out_dir: args.get("out", "results"),
        items: args.get_usize("items", 15)?,
        requests: args.get_usize("requests", 5)?,
        ..Default::default()
    };
    if let Some(models) = args.flags.get("models") {
        opts.models = models.split(',').map(|s| s.trim().to_string()).collect();
    }
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let text = experiments::run(id, &opts).with_context(|| format!("experiment {id}"))?;
        println!("{text}");
        println!(
            "[{id}] done in {:.1}s -> {}/{id}.txt\n",
            t0.elapsed().as_secs_f64(),
            opts.out_dir
        );
    }
    Ok(())
}

fn usage() -> String {
    "dymoe — DyMoE edge MoE serving (paper reproduction)\n\
     \n\
     commands:\n\
     \x20 info        --model <name> [--artifacts DIR]\n\
     \x20 serve       --model <name> [--vram GB] [--requests N] [--strategy S] [--retention R]\n\
     \x20 serve-fleet --model <name> [--vram GB] [--requests N] [--rate R/S]\n\
     \x20             [--arrival poisson[:RATE] | bursty[:BASE:BURST:PERIOD:FRAC]\n\
     \x20              | ramp[:START:END:SECS] (bare names keep the classic one-rate\n\
     \x20              shorthands derived from --rate: bursty = 0.25x base / 4x burst\n\
     \x20              over a 30 s period with a 0.2 burst fraction, ramp = 0.2x -> 2x\n\
     \x20              over 60 s; parameterized specs ignore --rate)]\n\
     \x20             [--scenario steady | diurnal[:PERIOD[:AMP]]\n\
     \x20              | flash-crowd[:AT[:MAG[:DUR]]] | mixed[:SHARE]\n\
     \x20              | mixed-diurnal[:SHARE[:PERIOD[:AMP]]]\n\
     \x20              | mixed-flash[:SHARE[:AT[:MAG[:DUR]]]]\n\
     \x20              (multi-tenant load scenario; SHARE = interactive fraction of\n\
     \x20              requests and of --rate, batch requests carry the fleet SLO\n\
     \x20              relaxed by --batch-slo-scale and may be preempted by\n\
     \x20              interactive admissions under class-aware scheduling;\n\
     \x20              mutually exclusive with --arrival)]\n\
     \x20             [--batch-slo-scale F (batch-class SLO relaxation on --scenario\n\
     \x20              runs; >= 1, default 8)]\n\
     \x20             [--sessions N] [--sched fifo|rr|slo (fifo stays class-blind —\n\
     \x20              the no-priority baseline; rr/slo admit interactive first and\n\
     \x20              preempt batch decode slots when an interactive request waits)]\n\
     \x20             [--max-decode-batch N (1 = serial decode; default: --sessions)]\n\
     \x20             [--chunk-tokens N (0 = monolithic prefill, the default; N > 0\n\
     \x20              fuses N prompt tokens per tick with the decode batch)]\n\
     \x20             [--replicas N (edge-cluster size; 1 = classic single device)]\n\
     \x20             [--dispatch rr|jsq|affinity|predictive (cluster request routing;\n\
     \x20              predictive probes the layer-0 gate per arrival, routes to the\n\
     \x20              replica with the most predicted-expert bytes resident, and\n\
     \x20              pre-stages the misses into the shared host pool)]\n\
     \x20             [--probe-depth N (predictive only: experts predicted per probe;\n\
     \x20              0 = model top_k, the default)]\n\
     \x20             [--replica-hw VRAM_GB[:PCIE_GBPS[:GPU_TFLOPS[:HOST_GBPS]]]\n\
     \x20              (repeatable; specs cycle over replicas for a big.LITTLE\n\
     \x20              cluster; HOST_GBPS weights the replica's share of the shared\n\
     \x20              host-pool link)]\n\
     \x20             [--fail T@R (repeatable: replica R dies at virtual time T;\n\
     \x20              its queued + in-flight sessions re-dispatch to live replicas,\n\
     \x20              restarting with their original arrival times)]\n\
     \x20             [--drain T@R (repeatable: replica R stops receiving dispatches\n\
     \x20              at T and runs down what it already holds)]\n\
     \x20             [--parallel N (tick independent replicas on N worker threads;\n\
     \x20              bit-identical outcome to serial, wall-clock only)]\n\
     \x20             [--host-pool CAP_GB[:static|shared|pinned] (shared host-RAM\n\
     \x20              expert tier between the per-replica VRAM caches and SSD;\n\
     \x20              live replicas' PCIe lanes contend for one host link;\n\
     \x20              absent = no pool, bitwise-identical to before)]\n\
     \x20             [--json [PATH] (write cluster + per-replica summary JSON)]\n\
     \x20             [--trace-out PATH (write a Perfetto/chrome://tracing-loadable\n\
     \x20              Chrome trace: one process per replica, per-channel threads\n\
     \x20              incl. a distinct pcie-prefetch lane, session lifecycle flows,\n\
     \x20              churn instants, and per-tick counter tracks)]\n\
     \x20             [--ttft-slo S] [--tpot-slo S] [--strategy S] [--seed N]\n\
     \x20 trace-lint  <trace.json> (validate a --trace-out artifact)\n\
     \x20 timeline    --model <name> [--vram GB] [--strategy S]\n\
     \x20 experiment  <fig1|fig2|fig3|fig4|fig5|fig6|fig10|fig11|table1|table2|table3|all>\n\
     \x20             [--items N] [--requests N] [--models a,b] [--out DIR]\n"
        .to_string()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse_args(&argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-fleet") => cmd_serve_fleet(&args),
        Some("trace-lint") => cmd_trace_lint(&args),
        Some("timeline") => cmd_timeline(&args),
        Some("experiment") => cmd_experiment(&args),
        _ => {
            print!("{}", usage());
            Ok(())
        }
    }
}

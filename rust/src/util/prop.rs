//! Tiny property-testing driver (proptest is not vendored offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded
//! random inputs; on failure it reports the failing case seed so the case
//! can be replayed deterministically with `replay(seed, f)`.

use super::rng::Rng;

/// Run `f` against `cases` seeded RNGs; panics with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn replay<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("add-commutes", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn reports_failing_seed() {
        check("always-fails", 3, |_rng| {
            panic!("always-fails");
        });
    }
}

//! Micro-benchmark harness (criterion is not vendored in this offline
//! build).  Provides warmup, adaptive iteration counts, and median/mean/p95
//! reporting; used by every target under `rust/benches/`.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark `f`, auto-scaling iterations to fill ~`budget_ms` of wall time.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_nanos().max(1) as f64;
    let target_ns = budget_ms as f64 * 1e6;
    let iters = ((target_ns / first) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
    let p95 = samples[p95_idx];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        min_ns: samples[0],
    }
}

/// Print the standard bench header.
pub fn header(title: &str) {
    println!("\n### bench: {title}");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "name", "median", "mean", "p95"
    );
    println!("{}", "-".repeat(86));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 5);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}

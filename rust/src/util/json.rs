//! Minimal JSON parser / writer.
//!
//! The offline build has no `serde_json` (see Cargo.toml note), so this is
//! a small, strict, recursive-descent JSON implementation covering what the
//! artifact manifests, eval suites and experiment outputs need: objects,
//! arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing bytes at offset {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// Convenience: `[1, 2, 3]` -> `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN / Infinity tokens; `{n}` would
                    // emit `NaN` or `inf` and make the document
                    // unparseable.  Null is the closest representable
                    // value for "no meaningful number here".
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for emitting result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at offset {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at offset {}", self.pos),
                    }
                }
                b => {
                    // Collect the full UTF-8 sequence starting at `b`.
                    let extra = match b {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    let start = self.pos - 1;
                    self.pos += extra;
                    let slice = self
                        .bytes
                        .get(start..self.pos)
                        .ok_or_else(|| anyhow!("truncated utf8"))?;
                    out.push_str(std::str::from_utf8(slice)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        assert!(v.get("c").unwrap().as_bool().unwrap());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested_and_unicode() {
        let src = r#"{"k": {"inner": ["A", "ü"]}}"#;
        let v = Json::parse(src).unwrap();
        let arr = v.get("k").unwrap().get("inner").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_str().unwrap(), "A");
        assert_eq!(arr[1].as_str().unwrap(), "ü");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // A bare `write!("{n}")` on these produced `inf` / `NaN`
        // tokens, which this parser (and every strict JSON parser)
        // rejects — the document must stay machine-readable even when
        // a statistic is degenerate.
        for v in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let doc = obj(vec![("v", num(v))]);
            assert_eq!(doc.to_string(), r#"{"v":null}"#);
            let re = Json::parse(&doc.to_string()).unwrap();
            assert_eq!(*re.get("v").unwrap(), Json::Null);
        }
        // Finite values are untouched.
        assert_eq!(num(2.5).to_string(), "2.5");
        assert_eq!(num(3.0).to_string(), "3");
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[3, 4, 5]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![3, 4, 5]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }
}

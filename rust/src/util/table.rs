//! Aligned plain-text table formatter for experiment / bench output.

/// Builds a column-aligned table and renders it paper-style.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with sensible precision (`1.0193 s`, `65.6 ms`).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.4} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Format a speedup ratio, e.g. `3.44x`.
pub fn fmt_x(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let out = t.render();
        assert!(out.contains("== demo =="));
        assert!(out.contains("long-name"));
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(1.5), "1.5000 s");
        assert_eq!(fmt_secs(0.0656), "65.60 ms");
        assert_eq!(fmt_x(3.441), "3.44x");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

//! Zero-dependency utility substrates for the offline build: JSON, PRNG,
//! table formatting, micro-bench harness, property-test driver.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

//! Deterministic PRNG + the few distributions the workload generator needs.
//!
//! `rand` is not vendored in this offline build, so we implement
//! xoshiro256** seeded via SplitMix64 (the reference constructions from
//! Blackman & Vigna) plus Box-Muller normals and log-normal sampling for
//! the ShareGPT-like length model.

/// xoshiro256** seeded from a u64 via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space mean / sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (for arrival gaps).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(17);
            assert!(n < 17);
            let m = r.range(3, 9);
            assert!((3..=9).contains(&m));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(3);
        let picks = r.choose_k(10, 4);
        assert_eq!(picks.len(), 4);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }
}

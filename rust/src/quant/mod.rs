//! Precision tiers and group-wise RTN quantization (Rust mirror of
//! `python/compile/kernels/ref.py` — the two implementations are tested
//! against each other via golden vectors and round-trip bounds).
//!
//! The coordinator mostly uses this module for *byte accounting* (I/O
//! volume per precision drives every latency experiment) and for runtime
//! re-quantization in tests; the serving hot path streams pre-packed blobs
//! from the weight store.

use anyhow::{bail, Result};

/// Fidelity state of an expert, ordered from cheapest to most faithful.
/// `Skip` is the paper's "0-bit" assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Precision {
    Skip,
    Int2,
    Int4,
    Int8,
    Bf16,
}

impl Precision {
    pub const ALL_STORED: [Precision; 4] =
        [Precision::Bf16, Precision::Int8, Precision::Int4, Precision::Int2];

    pub fn bits(self) -> u32 {
        match self {
            Precision::Skip => 0,
            Precision::Int2 => 2,
            Precision::Int4 => 4,
            Precision::Int8 => 8,
            Precision::Bf16 => 16,
        }
    }

    pub fn is_quantized(self) -> bool {
        matches!(self, Precision::Int2 | Precision::Int4 | Precision::Int8)
    }

    /// Manifest / artifact name fragment ("bf16", "int4", ...).
    pub fn tag(self) -> &'static str {
        match self {
            Precision::Skip => "skip",
            Precision::Int2 => "int2",
            Precision::Int4 => "int4",
            Precision::Int8 => "int8",
            Precision::Bf16 => "bf16",
        }
    }

    pub fn from_tag(tag: &str) -> Result<Precision> {
        Ok(match tag {
            "skip" | "0" => Precision::Skip,
            "int2" | "2" => Precision::Int2,
            "int4" | "4" => Precision::Int4,
            "int8" | "8" => Precision::Int8,
            "bf16" | "16" => Precision::Bf16,
            _ => bail!("unknown precision tag {tag:?}"),
        })
    }

    /// `true` if `self` can serve a request for `wanted` without loss of
    /// the *requested* fidelity (the cache's conservative-reuse rule).
    pub fn satisfies(self, wanted: Precision) -> bool {
        self >= wanted
    }
}

/// Signed symmetric range for a bit width, e.g. 4 -> (-8, 7).
pub fn quant_range(bits: u32) -> (i32, i32) {
    let half = 1i32 << (bits - 1);
    (-half, half - 1)
}

/// Group-wise symmetric RTN quantization of `w[K, N]` (row-major), groups
/// of `group` rows sharing one scale per column.  Returns (q, scales) with
/// q unbiased in the symmetric range, scales `[K/group, N]`.
pub fn quantize_groupwise(
    w: &[f32],
    k: usize,
    n: usize,
    bits: u32,
    group: usize,
) -> (Vec<i32>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    assert_eq!(k % group, 0);
    let (lo, hi) = quant_range(bits);
    let n_groups = k / group;
    let mut scales = vec![0f32; n_groups * n];
    let mut q = vec![0i32; k * n];
    for g in 0..n_groups {
        for col in 0..n {
            let mut max_abs = 0f32;
            for r in 0..group {
                max_abs = max_abs.max(w[(g * group + r) * n + col].abs());
            }
            let scale = (max_abs / hi as f32).max(1e-10);
            scales[g * n + col] = scale;
            for r in 0..group {
                let idx = (g * group + r) * n + col;
                let v = (w[idx] / scale).round() as i32;
                q[idx] = v.clamp(lo, hi);
            }
        }
    }
    (q, scales)
}

/// Dequantize the output of [`quantize_groupwise`].
pub fn dequantize_groupwise(
    q: &[i32],
    scales: &[f32],
    k: usize,
    n: usize,
    group: usize,
) -> Vec<f32> {
    let mut w = vec![0f32; k * n];
    for r in 0..k {
        for col in 0..n {
            w[r * n + col] = q[r * n + col] as f32 * scales[(r / group) * n + col];
        }
    }
    w
}

/// Pack unbiased ints into u32 words, little-endian along K: element
/// `k = r*vpw + j` occupies bits `[bits*j, bits*(j+1))` of word `r`.
pub fn pack_words(q: &[i32], k: usize, n: usize, bits: u32) -> Vec<u32> {
    let vpw = (32 / bits) as usize;
    assert_eq!(k % vpw, 0);
    let offset = 1u32 << (bits - 1);
    let rows = k / vpw;
    let mut words = vec![0u32; rows * n];
    for r in 0..rows {
        for j in 0..vpw {
            for col in 0..n {
                let biased = (q[(r * vpw + j) * n + col] + offset as i32) as u32;
                words[r * n + col] |= biased << (bits as usize * j);
            }
        }
    }
    words
}

/// Inverse of [`pack_words`].
pub fn unpack_words(words: &[u32], rows: usize, n: usize, bits: u32) -> Vec<i32> {
    let vpw = (32 / bits) as usize;
    let mask = (1u32 << bits) - 1;
    let offset = (1u32 << (bits - 1)) as i32;
    let mut q = vec![0i32; rows * vpw * n];
    for r in 0..rows {
        for j in 0..vpw {
            for col in 0..n {
                let raw = (words[r * n + col] >> (bits as usize * j)) & mask;
                q[(r * vpw + j) * n + col] = raw as i32 - offset;
            }
        }
    }
    q
}

/// Byte accounting for one expert (3 matrices: d->ffn, d->ffn, ffn->d) at a
/// given precision — the I/O-volume model every latency experiment uses.
/// Matches `python/compile/quant.expert_logical_bytes`.
pub fn expert_bytes(d: usize, ffn: usize, group: usize, prec: Precision) -> u64 {
    let params = (3 * d * ffn) as u64;
    match prec {
        Precision::Skip => 0,
        Precision::Bf16 => 2 * params,
        p => {
            let packed = params * p.bits() as u64 / 8;
            let scales = ((d / group) * ffn * 2 + (ffn / group) * d) as u64 * 4;
            packed + scales
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn precision_ordering() {
        use Precision::*;
        assert!(Bf16 > Int8 && Int8 > Int4 && Int4 > Int2 && Int2 > Skip);
        assert!(Bf16.satisfies(Int4));
        assert!(!Int2.satisfies(Int4));
        assert!(Int4.satisfies(Int4));
    }

    #[test]
    fn tags_roundtrip() {
        for p in Precision::ALL_STORED {
            assert_eq!(Precision::from_tag(p.tag()).unwrap(), p);
        }
        assert!(Precision::from_tag("int3").is_err());
    }

    #[test]
    fn golden_vector_matches_python() {
        // Mirrors python/tests/test_quantize.py::test_golden_vectors:
        // w = arange(-16, 16) / 8 as a [32, 1] column, int4, group 32.
        let w: Vec<f32> = (-16..16).map(|i| i as f32 / 8.0).collect();
        let (q, s) = quantize_groupwise(&w, 32, 1, 4, 32);
        assert!((s[0] - 2.0 / 7.0).abs() < 1e-6);
        assert_eq!(q[0], -7); // round(-2.0 / (2/7)) = -7
        let words = pack_words(&q, 32, 1, 4);
        assert_eq!(words.len(), 4); // 32 values * 4 bits / 32-bit words
        let back = unpack_words(&words, 4, 1, 4);
        assert_eq!(back, q);
    }

    #[test]
    fn pack_unpack_roundtrip_all_bits() {
        prop::check("pack-roundtrip", 40, |rng| {
            let bits = [2u32, 4, 8][rng.below(3)];
            let (lo, hi) = quant_range(bits);
            let k = 32 * rng.range(1, 3);
            let n = rng.range(1, 5);
            let q: Vec<i32> = (0..k * n)
                .map(|_| lo + rng.below((hi - lo + 1) as usize) as i32)
                .collect();
            let words = pack_words(&q, k, n, bits);
            assert_eq!(words.len(), k * bits as usize / 32 * n);
            assert_eq!(unpack_words(&words, k * bits as usize / 32, n, bits), q);
        });
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        prop::check("rtn-error-bound", 25, |rng| {
            let bits = [2u32, 4, 8][rng.below(3)];
            let k = 64;
            let n = rng.range(1, 4);
            let w: Vec<f32> = (0..k * n)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();
            let (q, s) = quantize_groupwise(&w, k, n, bits, 32);
            let back = dequantize_groupwise(&q, &s, k, n, 32);
            for r in 0..k {
                for c in 0..n {
                    let err = (back[r * n + c] - w[r * n + c]).abs();
                    let scale = s[(r / 32) * n + c];
                    assert!(
                        err <= 0.5 * scale + 1e-6,
                        "err {err} scale {scale} bits {bits}"
                    );
                }
            }
        });
    }

    #[test]
    fn error_monotone_in_bits() {
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..64 * 4).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut errs = Vec::new();
        for bits in [8u32, 4, 2] {
            let (q, s) = quantize_groupwise(&w, 64, 4, bits, 32);
            let back = dequantize_groupwise(&q, &s, 64, 4, 32);
            let e: f32 = w
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / w.len() as f32;
            errs.push(e);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn expert_bytes_ordering_and_values() {
        let d = 4096;
        let ffn = 14336;
        let params = (3 * d * ffn) as u64;
        assert_eq!(expert_bytes(d, ffn, 32, Precision::Bf16), 2 * params);
        assert_eq!(expert_bytes(d, ffn, 32, Precision::Skip), 0);
        let b8 = expert_bytes(d, ffn, 32, Precision::Int8);
        let b4 = expert_bytes(d, ffn, 32, Precision::Int4);
        let b2 = expert_bytes(d, ffn, 32, Precision::Int2);
        assert!(b8 > b4 && b4 > b2 && b2 > 0);
        assert!(b8 > params); // packed + scale overhead
    }
}

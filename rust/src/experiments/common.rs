//! Shared machinery for the experiment drivers: engine factories over a
//! shared executor, eval sweeps, latency traces, result persistence.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{LowMode, PolicyConfig, SystemConfig, GB};
use crate::coordinator::engine::{Engine, EngineOptions};
use crate::coordinator::strategy::Strategy;
use crate::eval::{evaluate_suite, SuiteScore};
use crate::model::assets::ModelAssets;
use crate::model::executor::Executor;
use crate::workload::{load_suites, EvalSuite, TraceGen};

/// Options shared by every experiment driver.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub artifacts: String,
    pub out_dir: String,
    /// Items per eval suite for accuracy sweeps.
    pub items: usize,
    /// Requests per latency measurement.
    pub requests: usize,
    pub models: Vec<String>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            artifacts: "artifacts".into(),
            out_dir: "results".into(),
            items: 15,
            requests: 5,
            models: vec!["mixtral-mini".into(), "qwen-mini".into()],
        }
    }
}

/// A model loaded once and shared across engine configurations.
pub struct ModelCtx {
    pub assets: Arc<ModelAssets>,
    pub exec: Rc<Executor>,
    pub suites: Vec<EvalSuite>,
}

impl ModelCtx {
    pub fn load(opts: &ExpOptions, model: &str) -> Result<ModelCtx> {
        let assets = Arc::new(
            ModelAssets::load(&opts.artifacts, model)
                .with_context(|| format!("loading model {model}"))?,
        );
        let exec = Rc::new(Executor::new(assets.clone())?);
        let suites = load_suites(&opts.artifacts)?;
        Ok(ModelCtx { assets, exec, suites })
    }

    /// Engine with effectively unlimited VRAM (accuracy-only runs).
    pub fn accuracy_engine(&self, strategy: Box<dyn Strategy>) -> Result<Engine> {
        let mut sys = SystemConfig::edge_preset(&self.assets.manifest.model.name, 24)?;
        sys.hardware.vram_bytes = 4096 * GB;
        Engine::with_executor(
            &self.assets,
            sys,
            strategy,
            EngineOptions {
                collect_logits: true,
                strict_precision: true,
                ..Default::default()
            },
            self.exec.clone(),
        )
    }

    /// Engine with a real edge preset (latency runs).
    pub fn edge_engine(&self, vram_gb: u64, strategy: Box<dyn Strategy>) -> Result<Engine> {
        let sys = SystemConfig::edge_preset(&self.assets.manifest.model.name, vram_gb)?;
        Engine::with_executor(
            &self.assets,
            sys,
            strategy,
            EngineOptions::default(),
            self.exec.clone(),
        )
    }

    /// Evaluate every suite on an engine; returns per-suite scores.
    pub fn eval_all(
        &self,
        engine: &mut Engine,
        items: usize,
        reference: Option<&BTreeMap<String, Vec<Vec<i32>>>>,
    ) -> Result<Vec<SuiteScore>> {
        let mut out = Vec::new();
        for suite in &self.suites {
            let r = reference.and_then(|m| m.get(&suite.name)).map(|v| &v[..]);
            let (score, _) = evaluate_suite(engine, suite, items, r)?;
            out.push(score);
        }
        Ok(out)
    }

    /// BF16 reference predictions per suite (for agreement metrics).
    pub fn reference_predictions(
        &self,
        items: usize,
    ) -> Result<BTreeMap<String, Vec<Vec<i32>>>> {
        let mut engine = self.accuracy_engine(Box::new(
            crate::baselines::Uniform::new(crate::quant::Precision::Bf16),
        ))?;
        let mut map = BTreeMap::new();
        for suite in &self.suites {
            let (_, preds) = evaluate_suite(&mut engine, suite, items, None)?;
            map.insert(suite.name.clone(), preds);
        }
        Ok(map)
    }
}

/// Mean (TTFT, TPOT) over a deterministic ShareGPT-like trace.
pub fn measure_latency(engine: &mut Engine, requests: usize, seed: u64) -> Result<(f64, f64)> {
    let m = engine.model().clone();
    let mut gen = TraceGen::new(seed, m.max_seq.min(80), (m.max_cache - m.max_seq).min(16));
    let (mut ttft, mut tpot) = (0.0, 0.0);
    for _ in 0..requests {
        let r = gen.next_request();
        let o = engine.run(&r.prompt, r.max_new)?;
        ttft += o.ttft / requests as f64;
        tpot += o.tpot() / requests as f64;
    }
    Ok((ttft, tpot))
}

/// DyMoE policy helper for the standard configurations.
pub fn dymoe_policy(retention: f64, low: LowMode) -> PolicyConfig {
    PolicyConfig { retention, low_mode: low, ..Default::default() }
}

/// Persist an experiment's rendered text + JSON payload.
pub fn save(opts: &ExpOptions, id: &str, text: &str, json: &crate::util::json::Json) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(format!("{}/{id}.txt", opts.out_dir), text)?;
    std::fs::write(format!("{}/{id}.json", opts.out_dir), json.to_string())?;
    Ok(())
}

//! Design-choice ablations beyond the paper's Table 3 (DESIGN.md §9):
//! scan-resistant vs plain LRU caching, prefetch depth, and SSD-resident
//! experts — the knobs our reproduction had to pin down empirically.

use anyhow::Result;

use crate::config::{LowMode, PolicyConfig, SystemConfig};
use crate::coordinator::engine::{Engine, EngineOptions};
use crate::coordinator::strategy::{DyMoEStrategy, Strategy};
use crate::util::json::{arr, num, obj, s};
use crate::util::table::Table;

use super::common::{dymoe_policy, measure_latency, ExpOptions, ModelCtx};

/// Plain-LRU wrapper around DyMoE (disables only the SLRU cache mode).
struct DyMoEPlainLru(DyMoEStrategy);

impl Strategy for DyMoEPlainLru {
    fn name(&self) -> String {
        format!("{} [plain LRU]", self.0.name())
    }
    fn plan(&mut self, ctx: &crate::coordinator::strategy::LayerCtx) -> crate::coordinator::strategy::LayerPlan {
        self.0.plan(ctx)
    }
    fn wants_probe(&self) -> bool {
        self.0.wants_probe()
    }
    fn prefetch(&mut self, ctx: &crate::coordinator::strategy::PrefetchCtx) -> Vec<(usize, crate::quant::Precision)> {
        self.0.prefetch(ctx)
    }
    fn warm_residency(&self, l: usize, e: usize) -> Vec<(crate::model::assets::ExpertKey, crate::quant::Precision)> {
        self.0.warm_residency(l, e)
    }
    fn scan_resistant_cache(&self) -> bool {
        false // the one difference
    }
    fn begin_request(&mut self, p: crate::coordinator::Phase) {
        self.0.begin_request(p)
    }
}

/// `dymoe experiment ablation2`: cache mode x prefetch depth x storage tier.
pub fn ablation2(opts: &ExpOptions) -> Result<String> {
    let model = &opts.models[0];
    let ctx = ModelCtx::load(opts, model)?;
    let vram = 16;
    let mut out = String::new();
    let mut payload = Vec::new();

    // --- cache mode ---
    let mut t = Table::new(
        &format!("Ablation: scan-resistant (SLRU) vs plain LRU cache ({model} @ {vram} GB)"),
        &["cache", "TTFT (s)", "TPOT (s)", "hit rate"],
    );
    for (name, slru) in [("SLRU (DyMoE)", true), ("plain LRU", false)] {
        let policy = dymoe_policy(0.75, LowMode::Skip);
        let strat: Box<dyn Strategy> = if slru {
            Box::new(DyMoEStrategy::new(policy))
        } else {
            Box::new(DyMoEPlainLru(DyMoEStrategy::new(policy)))
        };
        let mut e = ctx.edge_engine(vram, strat)?;
        let (ttft, tpot) = measure_latency(&mut e, opts.requests, 11)?;
        t.row(vec![
            name.into(),
            format!("{ttft:.4}"),
            format!("{tpot:.4}"),
            format!("{:.3}", e.cache.stats.hit_rate()),
        ]);
        payload.push(obj(vec![
            ("arm", s(name)),
            ("ttft", num(ttft)),
            ("tpot", num(tpot)),
            ("hit_rate", num(e.cache.stats.hit_rate())),
        ]));
    }
    out.push_str(&t.render());
    out.push('\n');

    // --- prefetch depth ---
    let mut t = Table::new(
        "Ablation: prefetch depth (0 = auto/top_k)",
        &["depth", "TTFT (s)", "TPOT (s)", "prefetch acc"],
    );
    for depth in [0usize, 1, 2, 4, 8] {
        let policy = PolicyConfig {
            retention: 0.75,
            low_mode: LowMode::Skip,
            prefetch_depth: depth,
            ..Default::default()
        };
        let mut e = ctx.edge_engine(vram, Box::new(DyMoEStrategy::new(policy)))?;
        let (ttft, tpot) = measure_latency(&mut e, opts.requests, 11)?;
        t.row(vec![
            if depth == 0 { "auto".into() } else { format!("{depth}") },
            format!("{ttft:.4}"),
            format!("{tpot:.4}"),
            format!("{:.3}", e.prefetch_stats.accuracy()),
        ]);
        payload.push(obj(vec![
            ("arm", s(&format!("depth{depth}"))),
            ("ttft", num(ttft)),
            ("tpot", num(tpot)),
        ]));
    }
    out.push_str(&t.render());
    out.push('\n');

    // --- storage tier: host-RAM vs SSD-resident experts ---
    let mut t = Table::new(
        "Ablation: expert storage tier (NVMe staging before PCIe)",
        &["tier", "TTFT (s)", "TPOT (s)"],
    );
    for (name, ssd) in [("host RAM", false), ("SSD (NVMe)", true)] {
        let mut sys = SystemConfig::edge_preset(model, vram)?;
        sys.policy.ssd_resident = ssd;
        let policy = PolicyConfig {
            retention: 0.75,
            low_mode: LowMode::Skip,
            ssd_resident: ssd,
            ..Default::default()
        };
        let mut e = Engine::with_executor(
            &ctx.assets,
            sys,
            Box::new(DyMoEStrategy::new(policy)),
            EngineOptions::default(),
            ctx.exec.clone(),
        )?;
        let (ttft, tpot) = measure_latency(&mut e, opts.requests, 11)?;
        t.row(vec![name.into(), format!("{ttft:.4}"), format!("{tpot:.4}")]);
        payload.push(obj(vec![
            ("arm", s(name)),
            ("ttft", num(ttft)),
            ("tpot", num(tpot)),
        ]));
    }
    out.push_str(&t.render());

    super::common::save(opts, "ablation2", &out, &arr(payload))?;
    Ok(out)
}

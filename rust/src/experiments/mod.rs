//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §5 for the experiment index).  Each driver regenerates
//! its table/figure as aligned text (printed + saved to `results/<id>.txt`)
//! plus a machine-readable `results/<id>.json`.

pub mod ablations;
pub mod accuracy;
pub mod analysis;
pub mod common;
pub mod latency;

use anyhow::{bail, Result};

pub use common::ExpOptions;

/// All experiment ids, in the order the paper presents them.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig10", "fig11",
    "table1", "table2", "table3", "ablation2",
];

/// Run one experiment by id; returns the rendered text.
pub fn run(id: &str, opts: &ExpOptions) -> Result<String> {
    Ok(match id {
        "fig1" => latency::fig1(opts)?,
        "fig2" => latency::fig2(opts)?,
        "fig3" => accuracy::fig3(opts)?,
        "fig4" => analysis::fig4(opts)?,
        "fig5" => accuracy::fig5(opts)?,
        "fig6" => analysis::fig6(opts)?,
        "fig10" => latency::fig10(opts)?,
        "fig11" => accuracy::fig11(opts)?,
        "table1" => accuracy::table1(opts)?,
        "table2" => accuracy::table2(opts)?,
        "table3" => latency::table3(opts)?,
        "ablation2" => ablations::ablation2(opts)?,
        _ => bail!("unknown experiment {id:?}; known: {ALL:?}"),
    })
}

//! Accuracy experiments: Table 1 (uniform quantization), Table 2 (DyMoE
//! 4/0 vs 4/2 across retention ratios), Fig. 3 (pruning strategies),
//! Fig. 5 (layer-wise Int2 sensitivity), Fig. 11 (accuracy vs r).
//!
//! Accuracy here is the fidelity-metric stand-in documented in DESIGN.md
//! §2: exact-match / token accuracy on the deterministic pattern suites
//! (MMLU/CMMLU/GSM8K proxies) plus agreement with the BF16 reference.

use anyhow::Result;

use crate::baselines::Uniform;
use crate::config::LowMode;
use crate::coordinator::scheduler::Selection;
use crate::coordinator::strategy::{
    layer_major_residency, DyMoEStrategy, LayerCtx, LayerPlan, Strategy,
};
use crate::eval::mean_token_acc;
use crate::model::assets::ExpertKey;
use crate::quant::Precision;
use crate::util::json::{arr, num, obj, s};
use crate::util::table::Table;
use crate::workload::suite_role;

use super::common::{dymoe_policy, ExpOptions, ModelCtx};

/// Table 1: accuracy under uniform Int2 / Int4 / BF16.
pub fn table1(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Table 1: Accuracy under Uniform Quantization (token-acc / exact-match)",
        &["Suite", "Model", "Int2", "Int4", "BF16"],
    );
    let mut payload = Vec::new();
    for model in &opts.models {
        let ctx = ModelCtx::load(opts, model)?;
        let mut per_prec = Vec::new();
        for prec in [Precision::Int2, Precision::Int4, Precision::Bf16] {
            let mut engine = ctx.accuracy_engine(Box::new(Uniform::new(prec)))?;
            per_prec.push(ctx.eval_all(&mut engine, opts.items, None)?);
        }
        for (si, suite) in ctx.suites.iter().enumerate() {
            t.row(vec![
                format!("{} ({})", suite.name, suite_role(&suite.name)),
                model.clone(),
                format!(
                    "{:.4}/{:.2}",
                    per_prec[0][si].token_acc, per_prec[0][si].exact_match
                ),
                format!(
                    "{:.4}/{:.2}",
                    per_prec[1][si].token_acc, per_prec[1][si].exact_match
                ),
                format!(
                    "{:.4}/{:.2}",
                    per_prec[2][si].token_acc, per_prec[2][si].exact_match
                ),
            ]);
            payload.push(obj(vec![
                ("suite", s(&suite.name)),
                ("model", s(model)),
                ("int2", num(per_prec[0][si].token_acc)),
                ("int4", num(per_prec[1][si].token_acc)),
                ("bf16", num(per_prec[2][si].token_acc)),
            ]));
        }
    }
    let text = t.render();
    super::common::save(opts, "table1", &text, &arr(payload))?;
    Ok(text)
}

/// Table 2: DyMoE accuracy at 4/0 and 4/2 across retention ratios.
pub fn table2(opts: &ExpOptions) -> Result<String> {
    let ratios = [0.75, 0.9, 1.0];
    let mut t = Table::new(
        "Table 2: DyMoE accuracy (token-acc), r = average retention",
        &["Suite", "Model", "High/Low", "r=0.75", "r=0.9", "r=1.0"],
    );
    let mut payload = Vec::new();
    for model in &opts.models {
        let ctx = ModelCtx::load(opts, model)?;
        for low in [LowMode::Skip, LowMode::Int2] {
            let mut per_r = Vec::new();
            for &r in &ratios {
                let mut engine = ctx.accuracy_engine(Box::new(DyMoEStrategy::new(
                    dymoe_policy(r, low),
                )))?;
                per_r.push(ctx.eval_all(&mut engine, opts.items, None)?);
            }
            for (si, suite) in ctx.suites.iter().enumerate() {
                t.row(vec![
                    format!("{} ({})", suite.name, suite_role(&suite.name)),
                    model.clone(),
                    low.label().to_string(),
                    format!("{:.4}", per_r[0][si].token_acc),
                    format!("{:.4}", per_r[1][si].token_acc),
                    format!("{:.4}", per_r[2][si].token_acc),
                ]);
                payload.push(obj(vec![
                    ("suite", s(&suite.name)),
                    ("model", s(model)),
                    ("mode", s(low.label())),
                    ("r075", num(per_r[0][si].token_acc)),
                    ("r090", num(per_r[1][si].token_acc)),
                    ("r100", num(per_r[2][si].token_acc)),
                ]));
            }
        }
    }
    let text = t.render();
    super::common::save(opts, "table2", &text, &arr(payload))?;
    Ok(text)
}

/// Fig. 3: expert-pruning strategies vs retention ratio (full-precision
/// retained experts, pruned = skipped).  2x2 arms: {Random, Token-based}
/// selection x {Equal, Depth-based} allocation.
pub fn fig3(opts: &ExpOptions) -> Result<String> {
    let model = &opts.models[0];
    let ctx = ModelCtx::load(opts, model)?;
    let ratios = [0.25, 0.5, 0.625, 0.75, 0.875, 1.0];
    let arms: [(&str, Selection, bool); 4] = [
        ("Random/Equal", Selection::Random, false),
        ("Random/Depth", Selection::Random, true),
        ("Token/Equal (Token-based)", Selection::Importance, false),
        ("Token/Depth (Depth-based)", Selection::Importance, true),
    ];
    let mut t = Table::new(
        &format!("Fig 3: pruning strategies on {model} (mean token-acc)"),
        &["Strategy", "r=0.25", "r=0.5", "r=0.625", "r=0.75", "r=0.875", "r=1.0"],
    );
    let mut payload = Vec::new();
    for (name, sel, depth) in arms {
        let mut row = vec![name.to_string()];
        let mut series = Vec::new();
        for &r in &ratios {
            let mut policy = dymoe_policy(r, LowMode::Skip);
            policy.high = Precision::Bf16; // pure pruning, no quantization
            policy.depth_aware = depth;
            let mut strat = DyMoEStrategy::new(policy);
            strat.selection = sel;
            let mut engine = ctx.accuracy_engine(Box::new(strat))?;
            let acc = mean_token_acc(&ctx.eval_all(&mut engine, opts.items, None)?);
            row.push(format!("{acc:.4}"));
            series.push(num(acc));
        }
        t.row(row);
        payload.push(obj(vec![("strategy", s(name)), ("acc", arr(series))]));
    }
    let text = t.render();
    super::common::save(opts, "fig3", &text, &arr(payload))?;
    Ok(text)
}

/// Per-layer Int2 strategy for Fig. 5: every expert of one layer at Int2,
/// everything else BF16.
struct LayerInt2 {
    target_layer: usize,
}

impl Strategy for LayerInt2 {
    fn name(&self) -> String {
        format!("LayerInt2(L{})", self.target_layer)
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        let p = if ctx.layer == self.target_layer {
            Precision::Int2
        } else {
            Precision::Bf16
        };
        LayerPlan::uniform(ctx.n_experts, p)
    }

    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, Precision::Bf16)
    }
}

/// Fig. 5: layer-wise sensitivity — quantize one layer to Int2 at a time.
pub fn fig5(opts: &ExpOptions) -> Result<String> {
    let mut out = String::new();
    let mut payload = Vec::new();
    for model in &opts.models {
        let ctx = ModelCtx::load(opts, model)?;
        let n_layers = ctx.assets.manifest.model.n_layers;
        let mut t = Table::new(
            &format!("Fig 5: layer-wise Int2 sensitivity on {model}"),
            &["Layer", "mean token-acc", "mean answer NLL"],
        );
        // BF16 reference row
        let mut engine = ctx.accuracy_engine(Box::new(Uniform::new(Precision::Bf16)))?;
        let scores = ctx.eval_all(&mut engine, opts.items, None)?;
        let base_acc = mean_token_acc(&scores);
        let base_nll: f64 =
            scores.iter().map(|x| x.answer_nll).sum::<f64>() / scores.len() as f64;
        t.row(vec!["none".into(), format!("{base_acc:.4}"), format!("{base_nll:.4}")]);
        let mut series = Vec::new();
        for layer in 0..n_layers {
            let mut engine =
                ctx.accuracy_engine(Box::new(LayerInt2 { target_layer: layer }))?;
            let scores = ctx.eval_all(&mut engine, opts.items, None)?;
            let acc = mean_token_acc(&scores);
            let nll: f64 =
                scores.iter().map(|x| x.answer_nll).sum::<f64>() / scores.len() as f64;
            t.row(vec![format!("{layer}"), format!("{acc:.4}"), format!("{nll:.4}")]);
            series.push(obj(vec![("layer", num(layer as f64)), ("acc", num(acc)), ("nll", num(nll))]));
        }
        payload.push(obj(vec![
            ("model", s(model)),
            ("bf16_acc", num(base_acc)),
            ("layers", arr(series)),
        ]));
        out.push_str(&t.render());
        out.push('\n');
    }
    super::common::save(opts, "fig5", &out, &arr(payload))?;
    Ok(out)
}

/// Fig. 11: accuracy vs retention ratio for 4/0 and 4/2.
pub fn fig11(opts: &ExpOptions) -> Result<String> {
    let ratios = [0.5, 0.625, 0.75, 0.875, 1.0];
    let mut out = String::new();
    let mut payload = Vec::new();
    for model in &opts.models {
        let ctx = ModelCtx::load(opts, model)?;
        let mut t = Table::new(
            &format!("Fig 11: accuracy vs retention ratio on {model} (mean token-acc)"),
            &["Mode", "r=0.5", "r=0.625", "r=0.75", "r=0.875", "r=1.0"],
        );
        for low in [LowMode::Skip, LowMode::Int2] {
            let mut row = vec![low.label().to_string()];
            let mut series = Vec::new();
            for &r in &ratios {
                let mut engine = ctx.accuracy_engine(Box::new(DyMoEStrategy::new(
                    dymoe_policy(r, low),
                )))?;
                let acc = mean_token_acc(&ctx.eval_all(&mut engine, opts.items, None)?);
                row.push(format!("{acc:.4}"));
                series.push(num(acc));
            }
            t.row(row);
            payload.push(obj(vec![
                ("model", s(model)),
                ("mode", s(low.label())),
                ("acc", arr(series)),
            ]));
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    super::common::save(opts, "fig11", &out, &arr(payload))?;
    Ok(out)
}

//! Latency experiments: Fig. 1 (pipeline timelines), Fig. 2 (memory
//! demand), Fig. 10 (end-to-end vs baselines), Table 3 (ablation).

use anyhow::Result;

use crate::baselines::{
    AccelerateStatic, Fiddler, LoadOnDemand, MixtralOffloading, MoeInfinity, Uniform,
};
use crate::config::{LowMode, PolicyConfig, SystemConfig, GB};
use crate::coordinator::engine::EngineOptions;
use crate::coordinator::strategy::{DyMoEStrategy, Strategy};
use crate::quant::{expert_bytes, Precision};
use crate::util::json::{arr, num, obj, s};
use crate::util::table::Table;

use super::common::{dymoe_policy, measure_latency, ExpOptions, ModelCtx};

/// Fig. 2b: paper-scale memory demand vs edge VRAM budgets.
pub fn fig2(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Fig 2b: paper-scale memory demand (GB) vs edge VRAM",
        &["Model", "BF16", "Int8", "Int4", "Int2", "fits 12/16/24 GB (int4)"],
    );
    let mut payload = Vec::new();
    for model in &opts.models {
        let paper = crate::config::PaperModel::for_mini(model)?;
        let per_prec: Vec<f64> = [Precision::Bf16, Precision::Int8, Precision::Int4, Precision::Int2]
            .iter()
            .map(|&p| {
                let experts = (paper.n_layers * paper.n_experts) as f64
                    * expert_bytes(paper.d_model, paper.d_ffn, 128, p) as f64;
                (experts + paper.non_expert_bytes as f64) / GB as f64
            })
            .collect();
        let fits: Vec<String> = [12.0, 16.0, 24.0]
            .iter()
            .map(|&v| if per_prec[2] <= v { "yes" } else { "no" }.to_string())
            .collect();
        t.row(vec![
            paper.name.to_string(),
            format!("{:.1}", per_prec[0]),
            format!("{:.1}", per_prec[1]),
            format!("{:.1}", per_prec[2]),
            format!("{:.1}", per_prec[3]),
            fits.join("/"),
        ]);
        payload.push(obj(vec![
            ("model", s(paper.name)),
            ("bf16_gb", num(per_prec[0])),
            ("int8_gb", num(per_prec[1])),
            ("int4_gb", num(per_prec[2])),
            ("int2_gb", num(per_prec[3])),
        ]));
    }
    let text = t.render();
    super::common::save(opts, "fig2", &text, &arr(payload))?;
    Ok(text)
}

/// Fig. 1: qualitative pipeline comparison — ASCII timelines for
/// load-on-demand, prefetching-only, and DyMoE on one decode-heavy request.
pub fn fig1(opts: &ExpOptions) -> Result<String> {
    let model = &opts.models[0];
    let ctx = ModelCtx::load(opts, model)?;
    let vram = 16;
    let mut out = String::new();
    let arms: Vec<(&str, Box<dyn Strategy>)> = vec![
        ("(a) Load-on-Demand", Box::new(LoadOnDemand::new(Precision::Bf16))),
        (
            "(b) Cache + Prefetch (uniform precision)",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 1.0,
                dyquant_enabled: false,
                ..Default::default()
            })),
        ),
        (
            "(c) DyMoE (dynamic mixed precision)",
            Box::new(DyMoEStrategy::new(dymoe_policy(0.75, LowMode::Skip))),
        ),
    ];
    let mut payload = Vec::new();
    for (name, strat) in arms {
        let sys = SystemConfig::edge_preset(model, vram)?;
        let mut e = crate::coordinator::engine::Engine::with_executor(
            &ctx.assets,
            sys,
            strat,
            EngineOptions { record_timeline: true, ..Default::default() },
            ctx.exec.clone(),
        )?;
        let prompt: Vec<i32> = (0..32).map(|i| 1 + (i * 7) % 60).collect();
        let o = e.run(&prompt, 6)?;
        out.push_str(&format!(
            "{name}: TTFT={:.4}s TPOT={:.4}s\n{}\n",
            o.ttft,
            o.tpot(),
            e.timeline.render_ascii(100)
        ));
        payload.push(obj(vec![
            ("arm", s(name)),
            ("ttft", num(o.ttft)),
            ("tpot", num(o.tpot())),
        ]));
    }
    super::common::save(opts, "fig1", &out, &arr(payload))?;
    Ok(out)
}

fn fig10_systems(
    m: &crate::model::manifest::MiniModel,
) -> Vec<(&'static str, Box<dyn Strategy>)> {
    vec![
        (
            "DyMoE(4/0)",
            Box::new(DyMoEStrategy::new(dymoe_policy(0.75, LowMode::Skip))),
        ),
        (
            "DyMoE(4/2)",
            Box::new(DyMoEStrategy::new(dymoe_policy(0.75, LowMode::Int2))),
        ),
        ("Accelerate(int4)", Box::new(AccelerateStatic::new(Precision::Int4))),
        (
            "Mixtral-Offloading(int4)",
            Box::new(MixtralOffloading::new(Precision::Int4, m.top_k)),
        ),
        (
            "MoE-Infinity(int4)",
            Box::new(MoeInfinity::new(Precision::Int4, m.n_layers, m.n_experts, m.top_k)),
        ),
        ("Fiddler(bf16)", Box::new(Fiddler)),
    ]
}

/// Fig. 10: end-to-end TTFT / TPOT across models, VRAM budgets, systems.
pub fn fig10(opts: &ExpOptions) -> Result<String> {
    let mut out = String::new();
    let mut payload = Vec::new();
    for model in &opts.models {
        let ctx = ModelCtx::load(opts, model)?;
        let m = ctx.assets.manifest.model.clone();
        for vram in [12u64, 16, 24] {
            let mut t = Table::new(
                &format!("Fig 10: {model} @ {vram} GB"),
                &["System", "TTFT (s)", "TPOT (s)", "TTFT x", "TPOT x"],
            );
            let mut base = (0.0, 0.0);
            for (i, (name, strat)) in fig10_systems(&m).into_iter().enumerate() {
                let mut e = ctx.edge_engine(vram, strat)?;
                let (ttft, tpot) = measure_latency(&mut e, opts.requests, 11)?;
                if i == 0 {
                    base = (ttft, tpot);
                }
                t.row(vec![
                    name.to_string(),
                    format!("{ttft:.4}"),
                    format!("{tpot:.4}"),
                    format!("{:.2}x", ttft / base.0),
                    format!("{:.2}x", tpot / base.1),
                ]);
                payload.push(obj(vec![
                    ("model", s(model)),
                    ("vram_gb", num(vram as f64)),
                    ("system", s(name)),
                    ("ttft", num(ttft)),
                    ("tpot", num(tpot)),
                ]));
            }
            out.push_str(&t.render());
            out.push('\n');
        }
    }
    super::common::save(opts, "fig10", &out, &arr(payload))?;
    Ok(out)
}

/// Table 3: incremental ablation at 16 and 24 GB on the coarse model.
pub fn table3(opts: &ExpOptions) -> Result<String> {
    let model = &opts.models[0];
    let ctx = ModelCtx::load(opts, model)?;
    let rows: Vec<(&str, fn() -> Box<dyn Strategy>)> = vec![
        ("1. Load on Demand", || Box::new(LoadOnDemand::new(Precision::Int4))),
        ("2. Cache", || Box::new(Uniform::new(Precision::Int4))),
        ("3. Cache + Prefetch", || {
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 1.0,
                dyquant_enabled: false,
                prefetch_enabled: true,
                ..Default::default()
            }))
        }),
        ("4. Cache + Dyquant(4/2)", || {
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 0.75,
                low_mode: LowMode::Int2,
                prefetch_enabled: false,
                ..Default::default()
            }))
        }),
        ("5. Cache + Dyquant(4/2) + Prefetcher", || {
            Box::new(DyMoEStrategy::new(dymoe_policy(0.75, LowMode::Int2)))
        }),
        ("6. Cache + Dyquant(4/0) + Prefetcher", || {
            Box::new(DyMoEStrategy::new(dymoe_policy(0.75, LowMode::Skip)))
        }),
    ];
    let mut t = Table::new(
        &format!("Table 3: ablation on {model}"),
        &["Configuration", "16GB TTFT", "16GB TPOT", "24GB TTFT", "24GB TPOT"],
    );
    let mut payload = Vec::new();
    for (name, mk) in rows {
        let mut cells = vec![name.to_string()];
        let mut nums = Vec::new();
        for vram in [16u64, 24] {
            let mut e = ctx.edge_engine(vram, mk())?;
            let (ttft, tpot) = measure_latency(&mut e, opts.requests, 11)?;
            cells.push(format!("{ttft:.4}"));
            cells.push(format!("{tpot:.4}"));
            nums.push((vram, ttft, tpot));
        }
        t.row(cells);
        payload.push(obj(vec![
            ("config", s(name)),
            ("ttft16", num(nums[0].1)),
            ("tpot16", num(nums[0].2)),
            ("ttft24", num(nums[1].1)),
            ("tpot24", num(nums[1].2)),
        ]));
    }
    let text = t.render();
    super::common::save(opts, "table3", &text, &arr(payload))?;
    Ok(text)
}

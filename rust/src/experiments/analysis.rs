//! Observation experiments: Fig. 4 (heavy-hitter vs general-token routing
//! distributions) and Fig. 6 (adjacent-layer activation similarity +
//! look-ahead predictability).

use anyhow::Result;

use crate::coordinator::{importance, top_k_route, Route};
use crate::model::assets::ExpertKey;
use crate::quant::Precision;
use crate::util::json::{arr, num, obj, s};
use crate::util::table::Table;
use crate::workload::tokens;

use super::common::{ExpOptions, ModelCtx};

/// Everything observed at one layer during a BF16 prefill trace.
pub struct LayerTrace {
    pub routes: Vec<Route>,
    pub token_scores: Vec<f32>,
    pub gate_probs: Vec<f32>,
    /// Post-layer residual stream `[T, d]` (valid tokens only).
    pub hidden: Vec<f32>,
}

/// Replicate the engine's prefill numerics at BF16 (no timing) and record
/// per-layer routing/scores/hiddens for the observation figures.
pub fn trace_prefill(ctx: &ModelCtx, prompt: &[i32]) -> Result<Vec<LayerTrace>> {
    let m = ctx.assets.manifest.model.clone();
    let seq = prompt.len();
    let mut padded = prompt.to_vec();
    padded.resize(m.max_seq, 0);
    let mut h = ctx.exec.embed_seq(&padded)?;
    let d = m.d_model;
    let mut out = Vec::new();
    for layer in 0..m.n_layers {
        let po = ctx.exec.attn_prefill(layer, &h, seq)?;
        let routes: Vec<Route> = (0..seq)
            .map(|t| top_k_route(&po.gate_probs[t * m.n_experts..(t + 1) * m.n_experts], m.top_k))
            .collect();
        // mix all routed experts at bf16
        let mut mix = vec![0f32; m.max_seq * d];
        for (t, route) in routes.iter().enumerate() {
            for &(e, w) in route {
                let rows = [&po.moe_in[t * d..(t + 1) * d]];
                let y = ctx.exec.expert_ffn(ExpertKey::new(layer, e), Precision::Bf16, &rows)?;
                for (a, b) in mix[t * d..(t + 1) * d].iter_mut().zip(&y[0]) {
                    *a += w * b;
                }
            }
        }
        let mut next = po.h_resid.clone();
        for (a, b) in next.iter_mut().zip(&mix) {
            *a += b;
        }
        out.push(LayerTrace {
            routes,
            token_scores: po.token_scores[..seq].to_vec(),
            gate_probs: po.gate_probs.clone(),
            hidden: next[..seq * d].to_vec(),
        });
        h = next;
    }
    Ok(out)
}

/// Fig. 4: expert routing distributions of heavy-hitter vs general tokens
/// for two contrasting inputs.
pub fn fig4(opts: &ExpOptions) -> Result<String> {
    let model = &opts.models[0];
    let ctx = ModelCtx::load(opts, model)?;
    let m = ctx.assets.manifest.model.clone();
    let probe_layer = m.n_layers / 2;

    // Two inputs from different pattern domains (shifting hotspots).
    let mk_copy = {
        let seg: Vec<i32> = (0..20).map(|i| tokens::LETTER0 + (i * 5) % 30).collect();
        let mut p = vec![tokens::BOS, tokens::TAG_COPY];
        p.extend(&seg);
        p.push(tokens::DELIM);
        p.extend(&seg[..10]);
        p
    };
    let mk_arith = {
        let mut p = vec![tokens::BOS, tokens::TAG_ARITH];
        p.extend((0..30).map(|i| tokens::DIGIT0 + (3 + i * 2) % 10));
        p
    };

    let mut out = String::new();
    let mut payload = Vec::new();
    for (name, prompt) in [("input-A (copy)", mk_copy), ("input-B (arith)", mk_arith)] {
        let trace = trace_prefill(&ctx, &prompt)?;
        let lt = &trace[probe_layer];
        let seq = lt.routes.len();
        let hh = importance::heavy_hitters(&lt.token_scores, seq, (seq / 5).max(1));
        let is_hh: Vec<bool> = (0..seq).map(|t| hh.contains(&t)).collect();
        let mut heavy_load = vec![0usize; m.n_experts];
        let mut total_load = vec![0usize; m.n_experts];
        for (t, route) in lt.routes.iter().enumerate() {
            for &(e, _) in route {
                total_load[e] += 1;
                if is_hh[t] {
                    heavy_load[e] += 1;
                }
            }
        }
        let mut t = Table::new(
            &format!("Fig 4: {name}, layer {probe_layer} of {model}"),
            &["Expert", "total-token load", "heavy-hitter load"],
        );
        for e in 0..m.n_experts {
            t.row(vec![
                format!("E{e}"),
                format!("{}", total_load[e]),
                format!("{}", heavy_load[e]),
            ]);
        }
        // correlation between total load and heavy load (paper: high)
        let corr = pearson(
            &total_load.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &heavy_load.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        out.push_str(&t.render());
        out.push_str(&format!("load/heavy-hitter correlation: {corr:.3}\n\n"));
        payload.push(obj(vec![
            ("input", s(name)),
            ("total", arr(total_load.iter().map(|&x| num(x as f64)).collect::<Vec<_>>())),
            ("heavy", arr(heavy_load.iter().map(|&x| num(x as f64)).collect::<Vec<_>>())),
            ("correlation", num(corr)),
        ]));
    }
    super::common::save(opts, "fig4", &out, &arr(payload))?;
    Ok(out)
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma).powi(2)).sum();
    let vb: f64 = b.iter().map(|x| (x - mb).powi(2)).sum();
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Fig. 6: adjacent-layer hidden-state cosine similarity + Eq.-6 probe
/// top-k prediction overlap.
pub fn fig6(opts: &ExpOptions) -> Result<String> {
    let mut out = String::new();
    let mut payload = Vec::new();
    for model in &opts.models {
        let ctx = ModelCtx::load(opts, model)?;
        let m = ctx.assets.manifest.model.clone();
        let d = m.d_model;
        // a few prompts from the trace generator
        let mut gen = crate::workload::TraceGen::new(5, m.max_seq.min(64), 8);
        let n_prompts = 4;
        let mut cos_sum = vec![0f64; m.n_layers - 1];
        let mut probe_hits = vec![0usize; m.n_layers - 1];
        let mut probe_total = vec![0usize; m.n_layers - 1];
        for _ in 0..n_prompts {
            let r = gen.next_request();
            let trace = trace_prefill(&ctx, &r.prompt)?;
            let seq = trace[0].routes.len();
            for l in 0..m.n_layers - 1 {
                // mean token-wise cosine similarity h_l vs h_{l+1}
                let mut c = 0f64;
                for t in 0..seq {
                    c += cosine(
                        &trace[l].hidden[t * d..(t + 1) * d],
                        &trace[l + 1].hidden[t * d..(t + 1) * d],
                    );
                }
                cos_sum[l] += c / seq as f64 / n_prompts as f64;
                // Eq.-6 predictability: probe(l+1) from h_l vs actual routes
                let probe = ctx.exec.gate_probe(l + 1, &{
                    let mut padded = trace[l].hidden.clone();
                    padded.resize(m.max_seq * d, 0.0);
                    padded
                })?;
                for t in 0..seq {
                    let pred = top_k_route(&probe[t * m.n_experts..(t + 1) * m.n_experts], m.top_k);
                    let actual: std::collections::HashSet<usize> =
                        trace[l + 1].routes[t].iter().map(|&(e, _)| e).collect();
                    probe_hits[l] += pred.iter().filter(|&&(e, _)| actual.contains(&e)).count();
                    probe_total[l] += m.top_k;
                }
            }
        }
        let mut t = Table::new(
            &format!("Fig 6: adjacent-layer similarity on {model}"),
            &["Layer pair", "cosine sim", "probe top-k overlap"],
        );
        let mut series = Vec::new();
        for l in 0..m.n_layers - 1 {
            let overlap = probe_hits[l] as f64 / probe_total[l].max(1) as f64;
            t.row(vec![
                format!("{l}->{}", l + 1),
                format!("{:.4}", cos_sum[l]),
                format!("{overlap:.3}"),
            ]);
            series.push(obj(vec![
                ("layer", num(l as f64)),
                ("cos", num(cos_sum[l])),
                ("overlap", num(overlap)),
            ]));
        }
        payload.push(obj(vec![("model", s(model)), ("pairs", arr(series))]));
        out.push_str(&t.render());
        out.push('\n');
    }
    super::common::save(opts, "fig6", &out, &arr(payload))?;
    Ok(out)
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

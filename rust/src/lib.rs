//! # DyMoE — Dynamic Expert Orchestration with Mixed-Precision Quantization
//!
//! Reproduction of the DyMoE paper (see `DESIGN.md`): a three-layer
//! Rust + JAX + Pallas serving stack for MoE inference on edge devices.
//!
//! * **L3 (this crate)** — the coordinator: phase-adaptive expert
//!   importance estimation, depth-aware precision scheduling, the
//!   mixed-precision LRU cache, the look-ahead prefetcher, plus the
//!   offloading baselines the paper compares against, a memory-hierarchy /
//!   virtual-time substrate, the multi-session serving layer ([`serving`]:
//!   open-loop arrival traffic, continuous session scheduling, fleet SLO
//!   metrics), and the experiment drivers for every table and figure in
//!   the paper.
//! * **L2/L1 (python/, build-time only)** — the mini-MoE JAX model and its
//!   Pallas kernels, AOT-lowered to HLO text artifacts executed here via
//!   the PJRT CPU client ([`runtime`]).

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod eval;
pub mod experiments;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod serving;
pub mod trace;
pub mod util;
pub mod workload;

/// Convenient re-exports for examples and binaries.
pub mod prelude {
    pub use crate::config::{LowMode, PolicyConfig, ServingConfig, SystemConfig, GB};
    pub use crate::coordinator::engine::{Engine, EngineSession, RequestOutput};
    pub use crate::coordinator::strategy::{DyMoEStrategy, Strategy};
    pub use crate::model::assets::ModelAssets;
    pub use crate::quant::Precision;
    pub use crate::serving::arrival::{ArrivalGen, ArrivalProcess, TimedRequest};
    pub use crate::serving::policy::{DispatchKind, PolicyKind};
    pub use crate::serving::{
        run_cluster, run_fleet, ClusterOutcome, FleetConfig, FleetOutcome, ReplicaBreakdown,
    };
}

//! The four offloading baselines the paper compares against (§6.1), plus
//! the plain load-on-demand strawman from the ablation (Table 3, row 1).
//!
//! Each baseline re-implements the published *strategy* on our shared
//! substrate (same model, cache machinery, transfer channels, cost model)
//! so relative speedups are attributable to policy alone — see DESIGN.md
//! §2 for the per-system approximation notes:
//!
//! * [`LoadOnDemand`]      — fetch every routed expert, never cache.
//! * [`AccelerateStatic`]  — HF Accelerate: static device placement; VRAM
//!   holds a fixed prefix of layers, everything else streams on demand
//!   (no dynamic caching, no prefetch).
//! * [`MixtralOffloading`] — Eliseev & Mazur: LRU expert cache + one-layer
//!   speculative prefetch of the gate's likely experts, uniform precision.
//! * [`MoeInfinity`]       — Xue et al.: activation-aware prefetch driven
//!   by per-request + historical expert activation statistics (EAM).
//! * [`Fiddler`]           — Kamahori et al.: CPU–GPU co-execution; VRAM
//!   misses run on the host CPU instead of transferring weights.

use crate::coordinator::prefetcher::{predict_decode, predict_prefill};
use crate::coordinator::strategy::{
    layer_major_residency, LayerCtx, LayerPlan, PrefetchCtx, Strategy,
};
use crate::coordinator::Phase;
use crate::model::assets::ExpertKey;
use crate::quant::Precision;

/// Row 1 of Table 3: fetch each routed expert on demand, no reuse.
pub struct LoadOnDemand {
    pub precision: Precision,
}

impl LoadOnDemand {
    pub fn new(precision: Precision) -> Self {
        LoadOnDemand { precision }
    }
}

impl Strategy for LoadOnDemand {
    fn name(&self) -> String {
        format!("LoadOnDemand({})", self.precision.tag())
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        LayerPlan::uniform(ctx.n_experts, self.precision)
    }

    fn uses_cache(&self) -> bool {
        false
    }

    fn warm_residency(&self, _l: usize, _e: usize) -> Vec<(ExpertKey, Precision)> {
        Vec::new()
    }
}

/// HF-Accelerate-style static partition: the warm-filled prefix of layers
/// lives in VRAM permanently; everything else streams per use and is NOT
/// cached (device placement is fixed at load time).
pub struct AccelerateStatic {
    pub precision: Precision,
}

impl AccelerateStatic {
    pub fn new(precision: Precision) -> Self {
        AccelerateStatic { precision }
    }
}

impl Strategy for AccelerateStatic {
    fn name(&self) -> String {
        format!("Accelerate({})", self.precision.tag())
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        LayerPlan::uniform(ctx.n_experts, self.precision)
    }

    fn inserts_on_miss(&self) -> bool {
        false // placement is static
    }

    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, self.precision)
    }
}

/// Mixtral-Offloading: LRU cache + speculative next-layer prefetch using
/// the same hidden-state gate guess, at a uniform precision.
pub struct MixtralOffloading {
    pub precision: Precision,
    pub speculative_depth: usize,
}

impl MixtralOffloading {
    pub fn new(precision: Precision, top_k: usize) -> Self {
        MixtralOffloading { precision, speculative_depth: top_k }
    }
}

impl Strategy for MixtralOffloading {
    fn name(&self) -> String {
        format!("Mixtral-Offloading({})", self.precision.tag())
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        LayerPlan::uniform(ctx.n_experts, self.precision)
    }

    fn wants_probe(&self) -> bool {
        true
    }

    fn prefetch(&mut self, ctx: &PrefetchCtx) -> Vec<(usize, Precision)> {
        let picks = match ctx.phase {
            Phase::Decode => predict_decode(ctx.probe_probs, self.speculative_depth),
            Phase::Prefill => predict_prefill(
                ctx.probe_probs,
                ctx.seq_len,
                ctx.n_experts,
                ctx.top_k,
                self.speculative_depth,
            ),
        };
        picks.into_iter().map(|e| (e, self.precision)).collect()
    }

    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, self.precision)
    }
}

/// MoE-Infinity: activation-aware prefetching.  Expert activation counts
/// are tracked per request (sequence-level locality) and decayed across
/// requests (historical EAM); the prefetch score blends the Eq.-6 probe
/// with those statistics.
pub struct MoeInfinity {
    pub precision: Precision,
    pub prefetch_depth: usize,
    /// Decayed historical activation counts `[layer][expert]`.
    history: Vec<Vec<f64>>,
    /// Current-request activation counts.
    request: Vec<Vec<f64>>,
    pub history_weight: f64,
}

impl MoeInfinity {
    pub fn new(precision: Precision, n_layers: usize, n_experts: usize, top_k: usize) -> Self {
        MoeInfinity {
            precision,
            prefetch_depth: top_k + 2,
            history: vec![vec![0.0; n_experts]; n_layers],
            request: vec![vec![0.0; n_experts]; n_layers],
            history_weight: 0.5,
        }
    }
}

impl Strategy for MoeInfinity {
    fn name(&self) -> String {
        format!("MoE-Infinity({})", self.precision.tag())
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        for route in ctx.routes {
            for &(e, _) in route {
                self.request[ctx.layer][e] += 1.0;
            }
        }
        LayerPlan::uniform(ctx.n_experts, self.precision)
    }

    fn wants_probe(&self) -> bool {
        true
    }

    fn prefetch(&mut self, ctx: &PrefetchCtx) -> Vec<(usize, Precision)> {
        let m = ctx.n_experts;
        let hist = &self.history[ctx.next_layer];
        let req = &self.request[ctx.next_layer];
        let norm = |v: &[f64]| -> Vec<f64> {
            let s: f64 = v.iter().sum::<f64>().max(1e-9);
            v.iter().map(|x| x / s).collect()
        };
        let hn = norm(hist);
        let rn = norm(req);
        let mut probe_mean = vec![0f64; m];
        let rows = if ctx.phase == Phase::Prefill { ctx.seq_len } else { 1 };
        for t in 0..rows {
            for e in 0..m {
                probe_mean[e] += ctx.probe_probs[t * m + e] as f64 / rows as f64;
            }
        }
        let scores: Vec<f64> = (0..m)
            .map(|e| probe_mean[e] + self.history_weight * (hn[e] + rn[e]))
            .collect();
        crate::coordinator::importance::rank_desc(&scores)
            .into_iter()
            .take(self.prefetch_depth)
            .map(|e| (e, self.precision))
            .collect()
    }

    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, self.precision)
    }

    fn begin_request(&mut self, phase: Phase) {
        if phase == Phase::Prefill {
            // fold the finished request into the decayed history
            for (h_l, r_l) in self.history.iter_mut().zip(&mut self.request) {
                for (h, r) in h_l.iter_mut().zip(r_l.iter_mut()) {
                    *h = 0.8 * *h + *r;
                    *r = 0.0;
                }
            }
        }
    }
}

/// Fiddler: full-precision weights; experts not resident in VRAM execute
/// on the host CPU (moving activations, not weights).  No dynamic cache
/// updates — residency is the static warm fill, as in the published
/// system's GPU-resident expert subset.
pub struct Fiddler;

impl Strategy for Fiddler {
    fn name(&self) -> String {
        "Fiddler(bf16)".to_string()
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        LayerPlan {
            precision: vec![Precision::Bf16; ctx.n_experts],
            cpu_fallback: vec![true; ctx.n_experts],
        }
    }

    fn inserts_on_miss(&self) -> bool {
        false
    }

    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, Precision::Bf16)
    }
}

/// Uniform-precision, fully-dynamic LRU strategy (used by the accuracy
/// experiments as "uniform Int4 / Int2 / BF16" and as a cache-only
/// ablation arm).
pub struct Uniform {
    pub precision: Precision,
}

impl Uniform {
    pub fn new(precision: Precision) -> Self {
        Uniform { precision }
    }
}

impl Strategy for Uniform {
    fn name(&self) -> String {
        format!("Uniform({})", self.precision.tag())
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        LayerPlan::uniform(ctx.n_experts, self.precision)
    }

    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(gate: &'a [f32], routes: &'a [crate::coordinator::Route]) -> LayerCtx<'a> {
        LayerCtx {
            layer: 0,
            n_layers: 4,
            n_experts: gate.len(),
            top_k: 2,
            phase: Phase::Decode,
            routes,
            gate_probs: gate,
            token_scores: None,
        }
    }

    #[test]
    fn load_on_demand_never_caches() {
        let s = LoadOnDemand::new(Precision::Int4);
        assert!(!s.uses_cache());
        assert!(s.warm_residency(4, 8).is_empty());
    }

    #[test]
    fn accelerate_static_placement() {
        let s = AccelerateStatic::new(Precision::Int4);
        assert!(s.uses_cache());
        assert!(!s.inserts_on_miss());
        let res = s.warm_residency(2, 3);
        assert_eq!(res.len(), 6);
        assert_eq!(res[0].0, ExpertKey::new(0, 0));
    }

    #[test]
    fn mixtral_offloading_prefetches_gate_guess() {
        let mut s = MixtralOffloading::new(Precision::Int4, 2);
        let probe = [0.1f32, 0.6, 0.2, 0.1];
        let picks = s.prefetch(&PrefetchCtx {
            next_layer: 1,
            n_layers: 4,
            n_experts: 4,
            top_k: 2,
            phase: Phase::Decode,
            seq_len: 1,
            probe_probs: &probe,
        });
        assert_eq!(picks, vec![(1, Precision::Int4), (2, Precision::Int4)]);
    }

    #[test]
    fn moe_infinity_history_shapes_prefetch() {
        let mut s = MoeInfinity::new(Precision::Int4, 4, 4, 1);
        // observe heavy traffic to expert 3 on layer 1
        let gate = [0.25f32, 0.25, 0.25, 0.25];
        let routes = vec![vec![(3usize, 1.0f32)]];
        let mut c = ctx(&gate, &routes);
        c.layer = 1;
        for _ in 0..10 {
            s.plan(&c);
        }
        // flat probe: history must break the tie toward expert 3
        let probe = [0.25f32, 0.25, 0.25, 0.25];
        let picks = s.prefetch(&PrefetchCtx {
            next_layer: 1,
            n_layers: 4,
            n_experts: 4,
            top_k: 1,
            phase: Phase::Decode,
            seq_len: 1,
            probe_probs: &probe,
        });
        assert_eq!(picks[0].0, 3);
    }

    #[test]
    fn fiddler_falls_back_to_cpu() {
        let mut s = Fiddler;
        let gate = [0.5f32, 0.5];
        let routes = vec![vec![(0usize, 0.5f32), (1, 0.5)]];
        let plan = s.plan(&ctx(&gate, &routes));
        assert!(plan.cpu_fallback.iter().all(|&b| b));
        assert!(plan.precision.iter().all(|&p| p == Precision::Bf16));
    }
}

//! Bench over the accuracy path that regenerates Fig. 3's arms: eval
//! items/second through the serving engine for each pruning strategy and
//! precision tier — this exercises the quantized expert artifacts (L1
//! Pallas kernels) end to end on the `tiny` model.

use std::sync::Arc;
use std::time::Instant;

use dymoe::baselines::Uniform;
use dymoe::config::{LowMode, PolicyConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::coordinator::scheduler::Selection;
use dymoe::coordinator::strategy::{DyMoEStrategy, Strategy};
use dymoe::eval::evaluate_suite;
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::workload::load_suites;

fn arms() -> Vec<(&'static str, Box<dyn Strategy>)> {
    let prune = |sel: Selection, depth: bool| -> Box<dyn Strategy> {
        let mut policy = PolicyConfig {
            retention: 0.75,
            low_mode: LowMode::Skip,
            high: Precision::Bf16,
            depth_aware: depth,
            ..Default::default()
        };
        policy.prefetch_enabled = false;
        let mut s = DyMoEStrategy::new(policy);
        s.selection = sel;
        Box::new(s)
    };
    vec![
        ("uniform bf16", Box::new(Uniform::new(Precision::Bf16))),
        ("uniform int4", Box::new(Uniform::new(Precision::Int4))),
        ("uniform int2", Box::new(Uniform::new(Precision::Int2))),
        ("prune random/equal", prune(Selection::Random, false)),
        ("prune token/depth", prune(Selection::Importance, true)),
    ]
}

fn main() -> anyhow::Result<()> {
    let Ok(assets) = ModelAssets::load("artifacts", "mixtral-mini") else {
        eprintln!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    let assets = Arc::new(assets);
    let Ok(suites) = load_suites("artifacts") else {
        eprintln!("eval suites missing");
        return Ok(());
    };
    println!("### bench: fig3 accuracy-path throughput (mixtral-mini, 4 items/suite)");
    println!(
        "{:<22} {:>14} {:>14} {:>12}",
        "arm", "items/s", "ms/item", "token-acc"
    );
    println!("{}", "-".repeat(66));
    for (name, strat) in arms() {
        let mut sys = SystemConfig::edge_preset("mixtral-mini", 24)?;
        sys.hardware.vram_bytes = 4096 * GB;
        let mut e = Engine::with_options(
            &assets,
            sys,
            strat,
            EngineOptions { collect_logits: true, strict_precision: true, ..Default::default() },
        )?;
        let wall = Instant::now();
        let mut items = 0usize;
        let mut acc_sum = 0.0;
        for suite in &suites {
            let (score, _) = evaluate_suite(&mut e, suite, 4, None)?;
            items += score.items;
            acc_sum += score.token_acc;
        }
        let secs = wall.elapsed().as_secs_f64();
        println!(
            "{name:<22} {:>14.2} {:>14.2} {:>12.4}",
            items as f64 / secs,
            1e3 * secs / items as f64,
            acc_sum / suites.len() as f64
        );
    }
    Ok(())
}

//! End-to-end bench regenerating the Table-3 ablation arms (16 GB) with
//! host-side wall cost per arm.  Skips politely without artifacts.

use std::sync::Arc;
use std::time::Instant;

use dymoe::baselines::{LoadOnDemand, Uniform};
use dymoe::config::{LowMode, PolicyConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::{DyMoEStrategy, Strategy};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::workload::TraceGen;

fn arms() -> Vec<(&'static str, Box<dyn Strategy>)> {
    vec![
        ("1 LoadOnDemand", Box::new(LoadOnDemand::new(Precision::Int4))),
        ("2 +Cache", Box::new(Uniform::new(Precision::Int4))),
        (
            "3 +Prefetch",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 1.0,
                dyquant_enabled: false,
                ..Default::default()
            })),
        ),
        (
            "4 +Dyquant(4/2) no-pref",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 0.75,
                low_mode: LowMode::Int2,
                prefetch_enabled: false,
                ..Default::default()
            })),
        ),
        (
            "5 full (4/2)",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 0.75,
                low_mode: LowMode::Int2,
                ..Default::default()
            })),
        ),
        (
            "6 full (4/0)",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 0.75,
                low_mode: LowMode::Skip,
                ..Default::default()
            })),
        ),
    ]
}

fn main() -> anyhow::Result<()> {
    let Ok(assets) = ModelAssets::load("artifacts", "mixtral-mini") else {
        eprintln!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    let assets = Arc::new(assets);
    println!("### bench: table3 ablation (mixtral-mini @ 16 GB, 4 requests/arm)");
    println!(
        "{:<26} {:>12} {:>12} {:>12} {:>14}",
        "configuration", "TTFT (s)", "TPOT (s)", "hit rate", "wall/req (s)"
    );
    println!("{}", "-".repeat(80));
    for (name, strat) in arms() {
        let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        let mut e = Engine::new(&assets, sys, strat)?;
        let mut gen = TraceGen::new(11, 80, 12);
        let n = 4;
        let wall = Instant::now();
        let (mut ttft, mut tpot) = (0.0, 0.0);
        for _ in 0..n {
            let r = gen.next_request();
            let o = e.run(&r.prompt, r.max_new)?;
            ttft += o.ttft / n as f64;
            tpot += o.tpot() / n as f64;
        }
        println!(
            "{name:<26} {ttft:>12.4} {tpot:>12.4} {:>12.3} {:>14.3}",
            e.cache.stats.hit_rate(),
            wall.elapsed().as_secs_f64() / n as f64
        );
    }
    Ok(())
}

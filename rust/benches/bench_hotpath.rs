//! Hot-path micro-benchmarks for the L3 coordinator (in-tree harness —
//! criterion is not vendored in this offline build; see util::bench).
//!
//! These are the operations on the per-token critical path: routing,
//! importance scoring, precision scheduling, cache operations, prefetch
//! prediction, and the virtual-timeline bookkeeping.  Targets
//! (EXPERIMENTS.md §Perf): every policy decision well under 5 us so L3
//! never bottlenecks the simulated device.

use dymoe::coordinator::cache::MixedPrecisionCache;
use dymoe::coordinator::scheduler::{assign_precisions, layer_budget, Allocation, Selection};
use dymoe::coordinator::{importance, prefetcher, top_k_route};
use dymoe::memory::timeline::Channel;
use dymoe::model::assets::ExpertKey;
use dymoe::quant::{pack_words, quantize_groupwise, unpack_words, Precision};
use dymoe::util::bench::{bench, header};
use dymoe::util::rng::Rng;

fn main() {
    header("coordinator hot paths");
    let mut rng = Rng::new(7);

    // Routing: top-2 of 8 (Mixtral-shape) and top-8 of 128 (Qwen-shape).
    let probs8: Vec<f32> = (0..8).map(|_| rng.f64() as f32).collect();
    let probs128: Vec<f32> = (0..128).map(|_| rng.f64() as f32).collect();
    println!("{}", bench("top_k_route 8->2", 60, || {
        std::hint::black_box(top_k_route(&probs8, 2));
    }).report());
    println!("{}", bench("top_k_route 128->8", 60, || {
        std::hint::black_box(top_k_route(&probs128, 8));
    }).report());

    // Decode importance + scheduling (per layer per token).
    println!("{}", bench("decode importance + assign (M=8)", 60, || {
        let imp = importance::decode_importance(&probs8);
        let b = layer_budget(Allocation::DepthCosine, 4, 32, 0.75, 8);
        std::hint::black_box(assign_precisions(
            &imp, b, Selection::Importance, Precision::Int4, Precision::Int2,
            &mut rng,
        ));
    }).report());

    // Prefill importance over a full prompt.
    let scores: Vec<f32> = (0..96).map(|_| rng.f64() as f32).collect();
    let routes: Vec<Vec<(usize, f32)>> = (0..96)
        .map(|_| {
            let p: Vec<f32> = (0..8).map(|_| rng.f64() as f32).collect();
            top_k_route(&p, 2)
        })
        .collect();
    println!("{}", bench("prefill importance (96 tok, M=8)", 60, || {
        std::hint::black_box(importance::prefill_importance(&scores, &routes, 8, 0.2));
    }).report());

    // Prefetch predictions.
    let probe: Vec<f32> = (0..96 * 8).map(|_| rng.f64() as f32).collect();
    println!("{}", bench("predict_decode (M=8, t=2)", 60, || {
        std::hint::black_box(prefetcher::predict_decode(&probe[..8], 2));
    }).report());
    println!("{}", bench("predict_prefill (96 tok, M=8)", 60, || {
        std::hint::black_box(prefetcher::predict_prefill(&probe, 96, 8, 2, 6));
    }).report());

    // Cache operations at a realistic working set (64 experts).
    let mut cache = MixedPrecisionCache::new(64 * 90_000_000);
    for l in 0..8 {
        for e in 0..8 {
            cache.insert(ExpertKey::new(l, e), Precision::Int4, 88_000_000, 0.0);
        }
    }
    let mut i = 0usize;
    println!("{}", bench("cache lookup (hit)", 60, || {
        i = (i + 1) % 64;
        std::hint::black_box(cache.lookup(ExpertKey::new(i / 8, i % 8), Precision::Int4));
    }).report());
    let mut j = 0usize;
    println!("{}", bench("cache insert + evict", 60, || {
        j += 1;
        std::hint::black_box(cache.insert(
            ExpertKey::new(j % 8, j % 8),
            Precision::Int4,
            88_000_000,
            0.0,
        ));
    }).report());

    // Timeline scheduling.
    let mut ch = Channel::default();
    let mut t = 0.0_f64;
    println!("{}", bench("channel schedule", 60, || {
        t += 1e-4;
        std::hint::black_box(ch.schedule(t, 5e-5));
    }).report());

    // Quantization (runtime re-quantization path; d=256 x ffn=512 slab).
    let w: Vec<f32> = (0..256 * 512).map(|_| rng.normal() as f32 * 0.3).collect();
    println!("{}", bench("quantize_groupwise 256x512 int4", 200, || {
        std::hint::black_box(quantize_groupwise(&w, 256, 512, 4, 32));
    }).report());
    let (q, _s) = quantize_groupwise(&w, 256, 512, 4, 32);
    println!("{}", bench("pack_words 256x512 int4", 200, || {
        std::hint::black_box(pack_words(&q, 256, 512, 4));
    }).report());
    let words = pack_words(&q, 256, 512, 4);
    println!("{}", bench("unpack_words 256x512 int4", 200, || {
        std::hint::black_box(unpack_words(&words, 32, 512, 4));
    }).report());
}

//! Fleet-serving bench: sweep open-loop Poisson arrival rate against the
//! fleet's tail latency (p99 TTFT measured from arrival, queueing
//! included), goodput, and SLO attainment, for each scheduling policy.
//! This is the classic serving-paper "rate vs p99" curve, produced on the
//! co-simulated virtual timeline (deterministic under the fixed seed).
//!
//! Skips politely if `make artifacts` has not been run.

use std::sync::Arc;
use std::time::Instant;

use dymoe::config::{PolicyConfig, ServingConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::model::assets::ModelAssets;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess};
use dymoe::serving::policy::PolicyKind;
use dymoe::serving::{run_fleet, FleetConfig};
use dymoe::workload::TraceGen;

fn main() -> anyhow::Result<()> {
    let Ok(assets) = ModelAssets::load("artifacts", "mixtral-mini") else {
        eprintln!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    let assets = Arc::new(assets);
    let m = assets.manifest.model.clone();
    let requests = 16;
    let rates = [0.05, 0.1, 0.2, 0.4, 0.8];
    println!(
        "### bench: fleet serving (mixtral-mini, 16 GB, {requests} requests/point, \
         Poisson arrivals)"
    );
    println!(
        "{:<8} {:<6} {:>12} {:>12} {:>12} {:>12} {:>8} {:>12}",
        "rate", "sched", "TTFT p50", "TTFT p99", "queue mean", "goodput r/s", "SLO %", "wall (s)"
    );
    println!("{}", "-".repeat(92));
    for &rate in &rates {
        for policy in PolicyKind::ALL {
            let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
            let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
            let mut engine = Engine::new(&assets, sys, strat)?;
            let mut content =
                TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
            let trace = ArrivalGen::generate(
                0x5EED,
                ArrivalProcess::Poisson { rate },
                &mut content,
                requests,
            )?;
            let cfg = FleetConfig {
                serving: ServingConfig { max_sessions: 8, ..Default::default() },
                policy,
            };
            let wall = Instant::now();
            let outcome = run_fleet(&mut engine, trace, &cfg)?;
            println!(
                "{rate:<8} {:<6} {:>12.4} {:>12.4} {:>12.4} {:>12.3} {:>7.0}% {:>12.2}",
                policy.name(),
                outcome.metrics.ttft.percentile(50.0),
                outcome.metrics.ttft.percentile(99.0),
                outcome.metrics.queue_delay.mean(),
                outcome.metrics.goodput_rps(),
                outcome.metrics.slo_attainment() * 100.0,
                wall.elapsed().as_secs_f64(),
            );
        }
    }
    Ok(())
}

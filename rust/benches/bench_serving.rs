//! Fleet-serving bench: sweep open-loop Poisson arrival rate against the
//! fleet's tail latency (p99 TTFT measured from arrival, queueing
//! included), mean TPOT, goodput, SLO attainment, and cross-session
//! expert-reuse — for each scheduling policy, serial interleaved decode
//! (`max_decode_batch = 1`) versus cross-session batched decode, and
//! monolithic prefill (`chunk_tokens = 0`) versus chunked prefill.  This
//! is the classic serving-paper "rate vs p99" curve, produced on the
//! co-simulated virtual timeline (deterministic under the fixed seed).
//!
//! A **replica-scaling sweep** (1/2/4-replica clusters — fresh engines
//! sharing one compiled executor — under every dispatch policy on the
//! *same* seeded trace, reporting goodput, p99 TTFT, and the
//! load-imbalance statistic), a **churn sweep** (stable vs drain vs
//! fail of replica 0 at 2/4 replicas, the event timed mid-serve,
//! reporting the requeue count, lost-work tokens, and the tail-latency
//! hit), a **host-pool sweep** (independent caches vs the static /
//! shared-LRU / pinned `--host-pool` partitionings at one total budget
//! over 2/4/8 replicas with SSD-resident weights, reporting the pool
//! hit rate, SSD fills, link-contention stall, and mean TTFT — the
//! shared tier's edge over the static split is the tentpole signal),
//! a **predictive-dispatch sweep** (gate-probe routing with look-ahead
//! pool pre-staging vs the hash-affinity baseline over 2/4/8 replicas
//! with the host tier off or shared, reporting pool hit rate, SSD
//! fills, pre-stage counts and accuracy, and mean/p99 TTFT —
//! predictive's hit-rate and mean-TTFT edge at 4+ replicas with the
//! shared pool on is the acceptance signal),
//! an **event-driven sweep** (8/16/32-replica clusters run
//! through the retired min-clock lockstep loop, the event-driven
//! scheduler, and the event-driven scheduler on 4 worker threads —
//! reporting wall-clock per mode plus the [`ClusterOutcome::digest`]
//! outcome hash, which must match across all three), and a
//! **scenario sweep** (one seeded mixed-tenant flash-crowd trace served
//! under the class-blind fifo baseline vs the class-aware preemptive
//! slo policy at 2/4 replicas, reporting per-class SLO attainment,
//! preemption counts, and batch throughput — interactive attainment
//! strictly higher under slo, with batch degraded but never starved,
//! is the acceptance signal) close the file.
//!
//! `--json` runs a small fixed smoke configuration instead and writes
//! `BENCH_serving.json` (p50/p99 TTFT/TPOT, expert dedup ratio per
//! decode-batch setting, a chunked-vs-monolithic long-prompt
//! head-of-line sweep: p99 TPOT, worst inter-token stall, chunk and
//! mixed-tick counts per `chunk_tokens` setting, plus the
//! `replica_scaling_sweep`, `churn_sweep`, `host_pool_sweep`,
//! `predictive_dispatch_sweep`, `event_driven_sweep`, and
//! `scenario_sweep`) so CI can track the perf trajectory in a
//! machine-readable form.
//!
//! Skips politely if `make artifacts` has not been run.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use dymoe::config::{
    ChurnEvent, ChurnKind, HostPoolConfig, PolicyConfig, PoolPolicyKind, ServingConfig,
    SystemConfig, GB,
};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::model::assets::ModelAssets;
use dymoe::model::executor::Executor;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess, TenantClass, TimedRequest};
use dymoe::serving::metrics::SloTargets;
use dymoe::serving::policy::{DispatchKind, PolicyKind};
use dymoe::serving::{
    run_cluster, run_cluster_minclock, run_fleet, ClusterOutcome, FleetConfig, FleetOutcome,
    Scenario,
};
use dymoe::util::json::Json;
use dymoe::workload::{Request, TraceGen};

const OUT_PATH: &str = "BENCH_serving.json";

/// Replica-scaling sweep operating point, shared by the text-mode sweep
/// and the `--json` smoke mode so the two never silently diverge: a
/// dense arrival rate (the single replica must saturate for the scaling
/// win to show) over 1/2/4-replica clusters.
const SCALING_RATE: f64 = 0.8;
const SCALING_REPLICAS: [usize; 3] = [1, 2, 4];

/// One deterministic fleet run (fresh engine, fixed seeds).
fn run_point(
    assets: &Arc<ModelAssets>,
    rate: f64,
    policy: PolicyKind,
    max_decode_batch: usize,
    chunk_tokens: usize,
    requests: usize,
) -> anyhow::Result<FleetOutcome> {
    let m = assets.manifest.model.clone();
    let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
    let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
    let mut engine = Engine::new(assets, sys, strat)?;
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = ArrivalGen::generate(
        0x5EED,
        ArrivalProcess::Poisson { rate },
        &mut content,
        requests,
    )?;
    let cfg = FleetConfig {
        serving: ServingConfig {
            max_sessions: 8,
            max_decode_batch,
            chunk_tokens,
            ..Default::default()
        },
        policy,
        ..Default::default()
    };
    run_fleet(&mut engine, trace, &cfg)
}

/// One deterministic **cluster** run: `replicas` fresh engines sharing
/// one compiled executor, the same seeded trace for every point, one
/// dispatch policy, an optional churn schedule.  The replica-scaling
/// and churn sweeps drive this.
fn run_cluster_point(
    assets: &Arc<ModelAssets>,
    rate: f64,
    replicas: usize,
    dispatch: DispatchKind,
    requests: usize,
    churn: Vec<ChurnEvent>,
) -> anyhow::Result<ClusterOutcome> {
    let m = assets.manifest.model.clone();
    let exec = Rc::new(Executor::new(assets.clone())?);
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
        engines.push(Engine::with_executor(
            assets,
            sys,
            strat,
            EngineOptions::default(),
            exec.clone(),
        )?);
    }
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = ArrivalGen::generate(
        0x5EED,
        ArrivalProcess::Poisson { rate },
        &mut content,
        requests,
    )?;
    let cfg = FleetConfig {
        serving: ServingConfig {
            max_sessions: 8,
            max_decode_batch: 8,
            churn,
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch,
    };
    run_cluster(&mut engines, trace, &cfg)
}

/// The host-pool sweep: independent caches (`none`) vs the three
/// `--host-pool` partitioning policies at the same total host budget,
/// over growing clusters.  SSD-resident weights make the host tier the
/// only thing between a VRAM miss and an NVMe fill, so the shared
/// pool's cross-replica reuse (higher hit rate, lower mean TTFT than
/// the static per-replica split) is the acceptance signal CI tracks.
const HOST_POOL_REPLICAS: [usize; 3] = [2, 4, 8];
const HOST_POOL_CAP_GB: f64 = 2.0;
const HOST_POOL_MODES: [&str; 4] = ["none", "static", "shared", "pinned"];

fn host_pool_for(mode: &str) -> Option<HostPoolConfig> {
    let policy = match mode {
        "none" => return None,
        "static" => PoolPolicyKind::Static,
        "shared" => PoolPolicyKind::Shared,
        "pinned" => PoolPolicyKind::Pinned,
        _ => unreachable!("unknown host-pool mode {mode}"),
    };
    Some(HostPoolConfig {
        capacity_bytes: (HOST_POOL_CAP_GB * GB as f64) as u64,
        policy,
    })
}

/// One cluster run for the host-pool sweep: like [`run_cluster_point`]
/// (fresh engines on one compiled executor, same seeded trace, rr
/// dispatch so every replica sees similar traffic) but with
/// `ssd_resident` weights and an optional host pool between the VRAM
/// caches and SSD.
fn run_host_pool_point(
    assets: &Arc<ModelAssets>,
    replicas: usize,
    requests: usize,
    mode: &str,
) -> anyhow::Result<ClusterOutcome> {
    let m = assets.manifest.model.clone();
    let exec = Rc::new(Executor::new(assets.clone())?);
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let mut sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        sys.policy.ssd_resident = true;
        let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
        engines.push(Engine::with_executor(
            assets,
            sys,
            strat,
            EngineOptions::default(),
            exec.clone(),
        )?);
    }
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = ArrivalGen::generate(
        0x5EED,
        ArrivalProcess::Poisson { rate: SCALING_RATE },
        &mut content,
        requests,
    )?;
    let cfg = FleetConfig {
        serving: ServingConfig {
            max_sessions: 8,
            max_decode_batch: 8,
            host_pool: host_pool_for(mode),
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch: DispatchKind::RoundRobin,
    };
    run_cluster(&mut engines, trace, &cfg)
}

/// The predictive-dispatch sweep: gate-probe routing with look-ahead
/// pool pre-staging against the hash-affinity baseline, with the host
/// tier off or shared, over growing clusters.  Predictive's edge —
/// more pool hits and a lower mean TTFT because the probed experts
/// start staging into the shared tier at dispatch time, before the
/// request is even admitted — at 4+ replicas with the shared pool on
/// is the acceptance signal CI tracks.
const PREDICTIVE_REPLICAS: [usize; 3] = [2, 4, 8];
const PREDICTIVE_DISPATCHES: [DispatchKind; 2] =
    [DispatchKind::ExpertAffinity, DispatchKind::Predictive];
const PREDICTIVE_POOL_MODES: [&str; 2] = ["none", "shared"];

/// One cluster run for the predictive-dispatch sweep: the host-pool
/// sweep's construction (fresh engines on one compiled executor,
/// SSD-resident weights, same seeded trace) under the given dispatch
/// policy, with the host tier either absent or a shared LRU at the
/// host-pool sweep's budget.
fn run_predictive_point(
    assets: &Arc<ModelAssets>,
    replicas: usize,
    requests: usize,
    dispatch: DispatchKind,
    pool_mode: &str,
) -> anyhow::Result<ClusterOutcome> {
    let m = assets.manifest.model.clone();
    let exec = Rc::new(Executor::new(assets.clone())?);
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let mut sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        sys.policy.ssd_resident = true;
        let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
        engines.push(Engine::with_executor(
            assets,
            sys,
            strat,
            EngineOptions::default(),
            exec.clone(),
        )?);
    }
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = ArrivalGen::generate(
        0x5EED,
        ArrivalProcess::Poisson { rate: SCALING_RATE },
        &mut content,
        requests,
    )?;
    let cfg = FleetConfig {
        serving: ServingConfig {
            max_sessions: 8,
            max_decode_batch: 8,
            host_pool: host_pool_for(pool_mode),
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch,
    };
    run_cluster(&mut engines, trace, &cfg)
}

/// The churn sweep's scenarios: a stable cluster, a graceful drain of
/// replica 0, and a hard failure of replica 0, each at the same
/// mid-trace instant (a fraction of the stable run's makespan, so the
/// event genuinely lands inside the serving window).
const CHURN_REPLICAS: [usize; 2] = [2, 4];
const CHURN_AT_FRACTION: f64 = 0.25;

fn churn_for(scenario: &str, at: f64) -> Vec<ChurnEvent> {
    match scenario {
        "stable" => Vec::new(),
        "drain" => vec![ChurnEvent { at, replica: 0, kind: ChurnKind::Drain }],
        "fail" => vec![ChurnEvent { at, replica: 0, kind: ChurnKind::Fail }],
        _ => unreachable!("unknown churn scenario {scenario}"),
    }
}

/// The event-driven sweep's cluster sizes: big enough that the retired
/// min-clock loop's per-iteration full scan (and its ticking of one
/// replica at a time while the rest idle-wait) costs real wall-clock,
/// so the event queue's "idle replicas cost nothing" win shows.
const EVENT_REPLICAS: [usize; 3] = [8, 16, 32];
const EVENT_MODES: [&str; 3] = ["minclock", "event", "parallel"];

/// One cluster run for the event-driven sweep.  Every mode builds its
/// engines identically — one compiled executor **per replica** (the
/// parallel mode requires distinct executors; keeping the serial modes
/// on the same construction keeps wall-clocks comparable) — and serves
/// the same seeded trace under jsq dispatch.  `mode` picks the
/// scheduler: `"minclock"` (the retired lockstep reference loop),
/// `"event"` (the event-driven scheduler, serial), `"parallel"` (the
/// event-driven scheduler on 4 worker threads).  Returns the outcome
/// plus the run's wall-clock seconds.
fn run_event_point(
    assets: &Arc<ModelAssets>,
    replicas: usize,
    requests: usize,
    mode: &str,
) -> anyhow::Result<(ClusterOutcome, f64)> {
    let m = assets.manifest.model.clone();
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
        let exec = Rc::new(Executor::new(assets.clone())?);
        engines.push(Engine::with_executor(
            assets,
            sys,
            strat,
            EngineOptions::default(),
            exec,
        )?);
    }
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = ArrivalGen::generate(
        0x5EED,
        ArrivalProcess::Poisson { rate: SCALING_RATE },
        &mut content,
        requests,
    )?;
    let cfg = FleetConfig {
        serving: ServingConfig {
            max_sessions: 8,
            max_decode_batch: 8,
            parallel: if mode == "parallel" { 4 } else { 1 },
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch: DispatchKind::JoinShortestQueue,
    };
    let wall = Instant::now();
    let o = if mode == "minclock" {
        run_cluster_minclock(&mut engines, trace, &cfg)?
    } else {
        run_cluster(&mut engines, trace, &cfg)?
    };
    Ok((o, wall.elapsed().as_secs_f64()))
}

/// The head-of-line scenario: short-prompt decoders plus one long
/// prompt (the whole `max_seq` bucket), all arriving at t = 0, run
/// chunked vs monolithic on fresh engines.
fn run_hol_point(
    assets: &Arc<ModelAssets>,
    chunk_tokens: usize,
) -> anyhow::Result<FleetOutcome> {
    let m = assets.manifest.model.clone();
    let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
    let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
    let mut engine = Engine::new(assets, sys, strat)?;
    let n_short = 4usize;
    let short_new = (m.max_cache - m.max_seq).clamp(1, 8);
    let long_new = (m.max_cache - m.max_seq).clamp(1, 2);
    let mut trace: Vec<TimedRequest> = (0..n_short)
        .map(|i| {
            TimedRequest::new(
                i,
                0.0,
                Request { prompt: vec![1, 10 + (3 * i as i32) % 40], max_new: short_new },
            )
        })
        .collect();
    trace.push(TimedRequest::new(
        n_short,
        0.0,
        Request {
            prompt: (0..m.max_seq).map(|i| 1 + (i as i32 * 7) % 60).collect(),
            max_new: long_new,
        },
    ));
    let cfg = FleetConfig {
        serving: ServingConfig {
            max_sessions: n_short + 1,
            max_decode_batch: n_short,
            chunk_tokens,
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        ..Default::default()
    };
    run_fleet(&mut engine, trace, &cfg)
}

/// The scenario sweep: one seeded mixed-tenant flash-crowd trace (a
/// 50/50 interactive/batch split on the base rate, the interactive
/// class spiking 4x at t = 5 s for 10 s) served under the class-blind
/// fifo baseline and the class-aware preemptive slo policy at 2 and 4
/// replicas.  Small slots (4 sessions, decode batch 4) make the flash
/// genuinely contend for admission, which is where priority admission
/// and batch-decode preemption earn their keep: interactive SLO
/// attainment strictly higher under slo than under fifo, with batch
/// throughput degraded by a bounded, reported amount (every batch
/// request still completes — request conservation is checked by the
/// cluster loop itself).
const SCENARIO_REPLICAS: [usize; 2] = [2, 4];
const SCENARIO_POLICIES: [PolicyKind; 2] = [PolicyKind::Fifo, PolicyKind::SloAware];
const SCENARIO_SPEC: &str = "mixed-flash:0.5:5:4:10";

fn run_scenario_point(
    assets: &Arc<ModelAssets>,
    replicas: usize,
    requests: usize,
    policy: PolicyKind,
) -> anyhow::Result<ClusterOutcome> {
    let m = assets.manifest.model.clone();
    let exec = Rc::new(Executor::new(assets.clone())?);
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
        engines.push(Engine::with_executor(
            assets,
            sys,
            strat,
            EngineOptions::default(),
            exec.clone(),
        )?);
    }
    let serving = ServingConfig {
        max_sessions: 4,
        max_decode_batch: 4,
        ..Default::default()
    };
    let scenario = Scenario::from_cli(
        SCENARIO_SPEC,
        SCALING_RATE,
        SloTargets { ttft_s: serving.ttft_slo_s, tpot_s: serving.tpot_slo_s },
        serving.batch_slo_scale,
    )?;
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = scenario.generate(0x5EED, &mut content, requests)?;
    let cfg = FleetConfig {
        serving,
        policy,
        dispatch: DispatchKind::JoinShortestQueue,
    };
    run_cluster(&mut engines, trace, &cfg)
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// The `--json` smoke mode: one rate, the SLO-aware policy, serial vs
/// batched decode — small enough for CI, rich enough to track.
fn smoke_json(assets: &Arc<ModelAssets>) -> anyhow::Result<Json> {
    let requests = 12;
    let rate = 0.4;
    let mut points = Vec::new();
    for &batch in &[1usize, 8] {
        let o = run_point(assets, rate, PolicyKind::SloAware, batch, 0, requests)?;
        let mut p = BTreeMap::new();
        p.insert("max_decode_batch".to_string(), num(batch as f64));
        p.insert("ttft_p50_s".to_string(), num(o.metrics.ttft.percentile(50.0)));
        p.insert("ttft_p99_s".to_string(), num(o.metrics.ttft.percentile(99.0)));
        p.insert("tpot_p50_s".to_string(), num(o.metrics.tpot.percentile(50.0)));
        p.insert("tpot_p99_s".to_string(), num(o.metrics.tpot.percentile(99.0)));
        p.insert("tpot_mean_s".to_string(), num(o.metrics.tpot.mean()));
        p.insert("goodput_rps".to_string(), num(o.metrics.goodput_rps()));
        p.insert("throughput_tps".to_string(), num(o.metrics.throughput_tps()));
        p.insert("mean_decode_batch".to_string(), num(o.dedup.mean_batch()));
        p.insert(
            "expert_dedup_ratio".to_string(),
            num(o.dedup.expert_reuse_ratio()),
        );
        p.insert(
            "dedup_saved_fetches".to_string(),
            num(o.dedup.saved_fetches() as f64),
        );
        points.push(Json::Obj(p));
    }
    // Chunked-vs-monolithic long-prompt sweep (the head-of-line
    // scenario): 0 = monolithic prefill, then two chunk budgets.
    let mut hol_points = Vec::new();
    for &chunk in &[0usize, 4, 8] {
        let o = run_hol_point(assets, chunk)?;
        let mut p = BTreeMap::new();
        p.insert("chunk_tokens".to_string(), num(chunk as f64));
        p.insert("ttft_p99_s".to_string(), num(o.metrics.ttft.percentile(99.0)));
        p.insert("tpot_p99_s".to_string(), num(o.metrics.tpot.percentile(99.0)));
        p.insert("tpot_mean_s".to_string(), num(o.metrics.tpot.mean()));
        p.insert("stall_max_s".to_string(), num(o.metrics.stall.max()));
        p.insert("stall_p99_s".to_string(), num(o.metrics.stall.percentile(99.0)));
        p.insert(
            "queue_delay_mean_s".to_string(),
            num(o.metrics.queue_delay.mean()),
        );
        p.insert(
            "prefill_time_mean_s".to_string(),
            num(o.metrics.prefill_time.mean()),
        );
        p.insert("prefill_chunks".to_string(), num(o.phase.prefill_chunks as f64));
        p.insert("mean_chunk_tokens".to_string(), num(o.phase.mean_chunk()));
        p.insert("mixed_ticks".to_string(), num(o.phase.mixed_steps as f64));
        hol_points.push(Json::Obj(p));
    }
    // Replica-scaling sweep: the same seeded trace over 1/2/4-replica
    // clusters x every dispatch policy — the scaling win (higher
    // goodput, lower p99 TTFT at 4 replicas) is the acceptance signal.
    let mut scaling_points = Vec::new();
    for &replicas in &SCALING_REPLICAS {
        for dispatch in DispatchKind::ALL {
            let o = run_cluster_point(
                assets,
                SCALING_RATE,
                replicas,
                dispatch,
                requests,
                Vec::new(),
            )?;
            let mut p = BTreeMap::new();
            p.insert("replicas".to_string(), num(replicas as f64));
            p.insert("dispatch".to_string(), Json::Str(dispatch.name().to_string()));
            p.insert("completed".to_string(), num(o.fleet.metrics.completed as f64));
            p.insert("ttft_p50_s".to_string(), num(o.fleet.metrics.ttft.percentile(50.0)));
            p.insert("ttft_p99_s".to_string(), num(o.fleet.metrics.ttft.percentile(99.0)));
            p.insert("tpot_p99_s".to_string(), num(o.fleet.metrics.tpot.percentile(99.0)));
            p.insert("goodput_rps".to_string(), num(o.fleet.metrics.goodput_rps()));
            p.insert(
                "throughput_tps".to_string(),
                num(o.fleet.metrics.throughput_tps()),
            );
            p.insert(
                "slo_attainment".to_string(),
                num(o.fleet.metrics.slo_attainment()),
            );
            p.insert("load_imbalance".to_string(), num(o.load_imbalance));
            p.insert("util_gpu".to_string(), num(o.fleet.utilization.gpu));
            p.insert("util_pcie".to_string(), num(o.fleet.utilization.pcie));
            p.insert("util_nvme".to_string(), num(o.fleet.utilization.nvme));
            scaling_points.push(Json::Obj(p));
        }
    }
    // Churn sweep: fail vs drain vs stable at 2 and 4 replicas (jsq
    // dispatch, same seeded trace), the event timed at a fraction of
    // the stable run's makespan so it lands mid-serve.  The SLO cost of
    // churn — requeued sessions, lost work, tail-latency hit — is the
    // signal CI tracks.
    let mut churn_points = Vec::new();
    for &replicas in &CHURN_REPLICAS {
        let stable = run_cluster_point(
            assets,
            SCALING_RATE,
            replicas,
            DispatchKind::JoinShortestQueue,
            requests,
            Vec::new(),
        )?;
        let at = stable.fleet.metrics.makespan() * CHURN_AT_FRACTION;
        for scenario in ["stable", "drain", "fail"] {
            let o = if scenario == "stable" {
                stable.clone()
            } else {
                run_cluster_point(
                    assets,
                    SCALING_RATE,
                    replicas,
                    DispatchKind::JoinShortestQueue,
                    requests,
                    churn_for(scenario, at),
                )?
            };
            let mut p = BTreeMap::new();
            p.insert("scenario".to_string(), Json::Str(scenario.to_string()));
            p.insert("replicas".to_string(), num(replicas as f64));
            p.insert("event_at_s".to_string(), num(if scenario == "stable" { 0.0 } else { at }));
            p.insert("completed".to_string(), num(o.fleet.metrics.completed as f64));
            p.insert("ttft_p50_s".to_string(), num(o.fleet.metrics.ttft.percentile(50.0)));
            p.insert("ttft_p99_s".to_string(), num(o.fleet.metrics.ttft.percentile(99.0)));
            p.insert("tpot_p99_s".to_string(), num(o.fleet.metrics.tpot.percentile(99.0)));
            p.insert("goodput_rps".to_string(), num(o.fleet.metrics.goodput_rps()));
            p.insert("makespan_s".to_string(), num(o.fleet.metrics.makespan()));
            p.insert("queue_delay_mean_s".to_string(), num(o.fleet.metrics.queue_delay.mean()));
            p.insert("requeued".to_string(), num(o.churn.requeued as f64));
            p.insert(
                "lost_work_tokens".to_string(),
                num(o.churn.lost_work_tokens as f64),
            );
            p.insert("max_retries".to_string(), num(o.churn.max_retries as f64));
            p.insert("load_imbalance".to_string(), num(o.load_imbalance));
            churn_points.push(Json::Obj(p));
        }
    }
    // Host-pool sweep: independent caches vs static/shared/pinned host
    // tiers at the same total budget.  The shared pool's hit rate and
    // mean-TTFT edge over the static split is the tentpole signal.
    let mut host_pool_points = Vec::new();
    for &replicas in &HOST_POOL_REPLICAS {
        for mode in HOST_POOL_MODES {
            let o = run_host_pool_point(assets, replicas, requests, mode)?;
            let mut p = BTreeMap::new();
            p.insert("replicas".to_string(), num(replicas as f64));
            p.insert("mode".to_string(), Json::Str(mode.to_string()));
            let cap = if mode == "none" { 0.0 } else { HOST_POOL_CAP_GB };
            p.insert("cap_gb".to_string(), num(cap));
            p.insert("completed".to_string(), num(o.fleet.metrics.completed as f64));
            p.insert("ttft_mean_s".to_string(), num(o.fleet.metrics.ttft.mean()));
            p.insert("ttft_p99_s".to_string(), num(o.fleet.metrics.ttft.percentile(99.0)));
            p.insert("goodput_rps".to_string(), num(o.fleet.metrics.goodput_rps()));
            p.insert("pool_hit_rate".to_string(), num(o.pool.hit_rate()));
            p.insert("host_hits".to_string(), num(o.pool.host_hits as f64));
            p.insert("ssd_fills".to_string(), num(o.pool.ssd_fills as f64));
            p.insert("evictions".to_string(), num(o.pool.evictions as f64));
            p.insert(
                "staged_gb".to_string(),
                num(o.pool.inserted_bytes as f64 / GB as f64),
            );
            p.insert("link_stall_s".to_string(), num(o.pool.stall_s));
            p.insert("util_pcie".to_string(), num(o.fleet.utilization.pcie));
            p.insert("util_nvme".to_string(), num(o.fleet.utilization.nvme));
            host_pool_points.push(Json::Obj(p));
        }
    }
    // Predictive-dispatch sweep: gate-probe routing + look-ahead
    // pre-staging vs hash affinity, with the host tier off and shared.
    // Predictive's pool-hit-rate and mean-TTFT edge over affinity at
    // 4+ replicas with the shared pool on is the tentpole signal.
    let mut predictive_points = Vec::new();
    for &replicas in &PREDICTIVE_REPLICAS {
        for dispatch in PREDICTIVE_DISPATCHES {
            for mode in PREDICTIVE_POOL_MODES {
                let o = run_predictive_point(assets, replicas, requests, dispatch, mode)?;
                let mut p = BTreeMap::new();
                p.insert("replicas".to_string(), num(replicas as f64));
                p.insert("dispatch".to_string(), Json::Str(dispatch.name().to_string()));
                p.insert("pool".to_string(), Json::Str(mode.to_string()));
                p.insert("completed".to_string(), num(o.fleet.metrics.completed as f64));
                p.insert("ttft_mean_s".to_string(), num(o.fleet.metrics.ttft.mean()));
                p.insert("ttft_p99_s".to_string(), num(o.fleet.metrics.ttft.percentile(99.0)));
                p.insert("goodput_rps".to_string(), num(o.fleet.metrics.goodput_rps()));
                p.insert("pool_hit_rate".to_string(), num(o.pool.hit_rate()));
                p.insert("host_hits".to_string(), num(o.pool.host_hits as f64));
                p.insert("ssd_fills".to_string(), num(o.pool.ssd_fills as f64));
                p.insert("upgrades".to_string(), num(o.pool.replacements as f64));
                p.insert("prestaged".to_string(), num(o.pool.prestaged as f64));
                p.insert("prestage_used".to_string(), num(o.pool.prestage_used as f64));
                p.insert("prestage_evicted".to_string(), num(o.pool.prestage_evicted as f64));
                p.insert("prestage_accuracy".to_string(), num(o.pool.prestage_accuracy()));
                predictive_points.push(Json::Obj(p));
            }
        }
    }
    // Event-driven sweep: each cluster size runs the retired min-clock
    // loop once (the reference digest), then the event-driven scheduler
    // serial and on 4 workers.  CI tracks the wall-clock win; the
    // `matches_minclock` booleans are the bit-identity signal (the
    // equivalence tests enforce it — here it is recorded alongside the
    // timing so a regression shows up in the same artifact).
    let mut event_points = Vec::new();
    for &replicas in &EVENT_REPLICAS {
        let (base, base_wall) = run_event_point(assets, replicas, requests, "minclock")?;
        let base_digest = base.digest();
        for mode in EVENT_MODES {
            let (o, wall) = if mode == "minclock" {
                (base.clone(), base_wall)
            } else {
                run_event_point(assets, replicas, requests, mode)?
            };
            let mut p = BTreeMap::new();
            p.insert("replicas".to_string(), num(replicas as f64));
            p.insert("mode".to_string(), Json::Str(mode.to_string()));
            p.insert("wall_ms".to_string(), num(wall * 1e3));
            p.insert("digest".to_string(), Json::Str(format!("{:016x}", o.digest())));
            p.insert(
                "matches_minclock".to_string(),
                Json::Bool(o.digest() == base_digest),
            );
            p.insert("completed".to_string(), num(o.fleet.metrics.completed as f64));
            p.insert("ttft_p99_s".to_string(), num(o.fleet.metrics.ttft.percentile(99.0)));
            p.insert("goodput_rps".to_string(), num(o.fleet.metrics.goodput_rps()));
            event_points.push(Json::Obj(p));
        }
    }
    // Scenario sweep: the same seeded mixed-tenant flash-crowd trace
    // under class-blind fifo vs the class-aware preemptive slo policy.
    // Interactive SLO attainment strictly higher under slo — with batch
    // merely degraded, never starved — is the acceptance signal CI
    // tracks.
    let mut scenario_points = Vec::new();
    for &replicas in &SCENARIO_REPLICAS {
        for policy in SCENARIO_POLICIES {
            let o = run_scenario_point(assets, replicas, 2 * requests, policy)?;
            let mut p = BTreeMap::new();
            p.insert("scenario".to_string(), Json::Str(SCENARIO_SPEC.to_string()));
            p.insert("replicas".to_string(), num(replicas as f64));
            p.insert("policy".to_string(), Json::Str(policy.name().to_string()));
            p.insert("completed".to_string(), num(o.fleet.metrics.completed as f64));
            p.insert(
                "throughput_tps".to_string(),
                num(o.fleet.metrics.throughput_tps()),
            );
            p.insert(
                "preemptions".to_string(),
                num(o.fleet.metrics.preemptions() as f64),
            );
            for (class, st) in &o.fleet.metrics.per_class {
                let k = class.name();
                p.insert(format!("{k}_completed"), num(st.completed as f64));
                p.insert(format!("{k}_slo_attainment"), num(st.slo_attainment()));
                p.insert(format!("{k}_ttft_p99_s"), num(st.ttft.percentile(99.0)));
                p.insert(
                    format!("{k}_queue_delay_mean_s"),
                    num(st.queue_delay.mean()),
                );
                p.insert(format!("{k}_tokens"), num(st.tokens_total as f64));
            }
            scenario_points.push(Json::Obj(p));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("model".to_string(), Json::Str("mixtral-mini".to_string()));
    root.insert("policy".to_string(), Json::Str("slo".to_string()));
    root.insert("requests_per_point".to_string(), num(requests as f64));
    root.insert("rate_rps".to_string(), num(rate));
    root.insert("scaling_rate_rps".to_string(), num(SCALING_RATE));
    root.insert("skipped".to_string(), Json::Bool(false));
    root.insert("points".to_string(), Json::Arr(points));
    root.insert("hol_long_prompt_sweep".to_string(), Json::Arr(hol_points));
    root.insert("replica_scaling_sweep".to_string(), Json::Arr(scaling_points));
    root.insert("churn_sweep".to_string(), Json::Arr(churn_points));
    root.insert("host_pool_sweep".to_string(), Json::Arr(host_pool_points));
    root.insert("predictive_dispatch_sweep".to_string(), Json::Arr(predictive_points));
    root.insert("event_driven_sweep".to_string(), Json::Arr(event_points));
    root.insert("scenario_sweep".to_string(), Json::Arr(scenario_points));
    Ok(Json::Obj(root))
}

fn main() -> anyhow::Result<()> {
    let json_mode = std::env::args().any(|a| a == "--json");
    let Ok(assets) = ModelAssets::load("artifacts", "mixtral-mini") else {
        eprintln!("artifacts missing; run `make artifacts` first");
        if json_mode {
            // Record the skip machine-readably rather than failing CI.
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(), Json::Str("serving".to_string()));
            root.insert("skipped".to_string(), Json::Bool(true));
            std::fs::write(OUT_PATH, Json::Obj(root).to_string())?;
            println!("wrote {OUT_PATH} (skipped: no artifacts)");
        }
        return Ok(());
    };
    let assets = Arc::new(assets);

    if json_mode {
        let j = smoke_json(&assets)?;
        std::fs::write(OUT_PATH, j.to_string())?;
        println!("{}", j.to_string());
        println!("wrote {OUT_PATH}");
        return Ok(());
    }

    let requests = 16;
    let rates = [0.05, 0.1, 0.2, 0.4, 0.8];
    let batches = [1usize, 8];
    let chunks = [0usize, 8];
    println!(
        "### bench: fleet serving (mixtral-mini, 16 GB, {requests} requests/point, \
         Poisson arrivals; decode batch 1 = serial interleaved, chunk 0 = \
         monolithic prefill)"
    );
    println!(
        "{:<8} {:<6} {:>6} {:>6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8} {:>8} {:>10}",
        "rate",
        "sched",
        "batch",
        "chunk",
        "TTFT p50",
        "TTFT p99",
        "TPOT mean",
        "stall max",
        "goodput r/s",
        "SLO %",
        "reuse",
        "wall (s)"
    );
    println!("{}", "-".repeat(129));
    for &rate in &rates {
        for policy in PolicyKind::ALL {
            for &batch in &batches {
                for &chunk in &chunks {
                    let wall = Instant::now();
                    let outcome = run_point(&assets, rate, policy, batch, chunk, requests)?;
                    println!(
                        "{rate:<8} {:<6} {batch:>6} {chunk:>6} {:>12.4} {:>12.4} {:>12.4} \
                         {:>12.4} {:>12.3} {:>7.0}% {:>7.2}x {:>10.2}",
                        policy.name(),
                        outcome.metrics.ttft.percentile(50.0),
                        outcome.metrics.ttft.percentile(99.0),
                        outcome.metrics.tpot.mean(),
                        outcome.metrics.stall.max(),
                        outcome.metrics.goodput_rps(),
                        outcome.metrics.slo_attainment() * 100.0,
                        outcome.dedup.expert_reuse_ratio(),
                        wall.elapsed().as_secs_f64(),
                    );
                }
            }
        }
    }
    println!();
    println!(
        "### head-of-line long-prompt sweep (slo policy, 4 short decoders + 1 \
         max_seq prompt at t=0)"
    );
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "chunk", "TPOT p99", "stall max", "TTFT p99", "chunks", "mixed"
    );
    for &chunk in &[0usize, 2, 4, 8] {
        let o = run_hol_point(&assets, chunk)?;
        println!(
            "{chunk:<8} {:>12.4} {:>12.4} {:>12.4} {:>8} {:>8}",
            o.metrics.tpot.percentile(99.0),
            o.metrics.stall.max(),
            o.metrics.ttft.percentile(99.0),
            o.phase.prefill_chunks,
            o.phase.mixed_steps,
        );
    }
    println!();
    println!(
        "### replica-scaling sweep (slo policy, Poisson {SCALING_RATE} r/s, \
         {requests} requests, same trace per point)"
    );
    println!(
        "{:<9} {:<9} {:>12} {:>12} {:>12} {:>10} {:>8} {:>10}",
        "replicas", "dispatch", "TTFT p99", "goodput r/s", "tok/s", "imbalance", "gpu %", "wall (s)"
    );
    for &replicas in &SCALING_REPLICAS {
        for dispatch in DispatchKind::ALL {
            let wall = Instant::now();
            let o = run_cluster_point(
                &assets,
                SCALING_RATE,
                replicas,
                dispatch,
                requests,
                Vec::new(),
            )?;
            println!(
                "{replicas:<9} {:<9} {:>12.4} {:>12.3} {:>12.1} {:>10.2} {:>7.0}% {:>10.2}",
                dispatch.name(),
                o.fleet.metrics.ttft.percentile(99.0),
                o.fleet.metrics.goodput_rps(),
                o.fleet.metrics.throughput_tps(),
                o.load_imbalance,
                o.fleet.utilization.gpu * 100.0,
                wall.elapsed().as_secs_f64(),
            );
        }
    }
    println!();
    println!(
        "### churn sweep (slo policy, jsq dispatch, Poisson {SCALING_RATE} r/s; replica 0 \
         drained or failed at {CHURN_AT_FRACTION} of the stable makespan)"
    );
    println!(
        "{:<9} {:<9} {:>12} {:>12} {:>12} {:>9} {:>10} {:>10}",
        "replicas",
        "scenario",
        "TTFT p99",
        "goodput r/s",
        "queue mean",
        "requeued",
        "lost tok",
        "wall (s)"
    );
    for &replicas in &CHURN_REPLICAS {
        let stable = run_cluster_point(
            &assets,
            SCALING_RATE,
            replicas,
            DispatchKind::JoinShortestQueue,
            requests,
            Vec::new(),
        )?;
        let at = stable.fleet.metrics.makespan() * CHURN_AT_FRACTION;
        for scenario in ["stable", "drain", "fail"] {
            let wall = Instant::now();
            let o = if scenario == "stable" {
                stable.clone()
            } else {
                run_cluster_point(
                    &assets,
                    SCALING_RATE,
                    replicas,
                    DispatchKind::JoinShortestQueue,
                    requests,
                    churn_for(scenario, at),
                )?
            };
            println!(
                "{replicas:<9} {scenario:<9} {:>12.4} {:>12.3} {:>12.4} {:>9} {:>10} {:>10.2}",
                o.fleet.metrics.ttft.percentile(99.0),
                o.fleet.metrics.goodput_rps(),
                o.fleet.metrics.queue_delay.mean(),
                o.churn.requeued,
                o.churn.lost_work_tokens,
                wall.elapsed().as_secs_f64(),
            );
        }
    }
    println!();
    println!(
        "### host-pool sweep (slo policy, rr dispatch, Poisson {SCALING_RATE} r/s, \
         ssd-resident weights; none = independent caches, else a {HOST_POOL_CAP_GB} GB \
         host tier split static / shared LRU / pinned)"
    );
    println!(
        "{:<9} {:<8} {:>9} {:>9} {:>9} {:>9} {:>11} {:>12} {:>12} {:>10}",
        "replicas",
        "mode",
        "hit rate",
        "hits",
        "fills",
        "evict",
        "stall (s)",
        "TTFT mean",
        "TTFT p99",
        "wall (s)"
    );
    for &replicas in &HOST_POOL_REPLICAS {
        for mode in HOST_POOL_MODES {
            let wall = Instant::now();
            let o = run_host_pool_point(&assets, replicas, requests, mode)?;
            println!(
                "{replicas:<9} {mode:<8} {:>9.3} {:>9} {:>9} {:>9} {:>11.4} {:>12.4} \
                 {:>12.4} {:>10.2}",
                o.pool.hit_rate(),
                o.pool.host_hits,
                o.pool.ssd_fills,
                o.pool.evictions,
                o.pool.stall_s,
                o.fleet.metrics.ttft.mean(),
                o.fleet.metrics.ttft.percentile(99.0),
                wall.elapsed().as_secs_f64(),
            );
        }
    }
    println!();
    println!(
        "### predictive-dispatch sweep (slo policy, Poisson {SCALING_RATE} r/s, \
         ssd-resident weights; gate-probe routing + look-ahead pre-staging vs \
         hash affinity, host pool off vs shared {HOST_POOL_CAP_GB} GB)"
    );
    println!(
        "{:<9} {:<11} {:<7} {:>9} {:>7} {:>7} {:>8} {:>7} {:>12} {:>12} {:>10}",
        "replicas",
        "dispatch",
        "pool",
        "hit rate",
        "hits",
        "fills",
        "staged",
        "used",
        "TTFT mean",
        "TTFT p99",
        "wall (s)"
    );
    for &replicas in &PREDICTIVE_REPLICAS {
        for dispatch in PREDICTIVE_DISPATCHES {
            for mode in PREDICTIVE_POOL_MODES {
                let wall = Instant::now();
                let o = run_predictive_point(&assets, replicas, requests, dispatch, mode)?;
                println!(
                    "{replicas:<9} {:<11} {mode:<7} {:>9.3} {:>7} {:>7} {:>8} {:>7} \
                     {:>12.4} {:>12.4} {:>10.2}",
                    dispatch.name(),
                    o.pool.hit_rate(),
                    o.pool.host_hits,
                    o.pool.ssd_fills,
                    o.pool.prestaged,
                    o.pool.prestage_used,
                    o.fleet.metrics.ttft.mean(),
                    o.fleet.metrics.ttft.percentile(99.0),
                    wall.elapsed().as_secs_f64(),
                );
            }
        }
    }
    println!();
    println!(
        "### event-driven sweep (slo policy, jsq dispatch, Poisson {SCALING_RATE} r/s, \
         {requests} requests; minclock = retired lockstep loop, event = next-event \
         scheduler, parallel = event on 4 workers; digests must match per row group)"
    );
    println!(
        "{:<9} {:<9} {:>10} {:>18} {:>8} {:>12}",
        "replicas", "mode", "wall (ms)", "digest", "match", "goodput r/s"
    );
    for &replicas in &EVENT_REPLICAS {
        let mut base_digest = 0u64;
        for mode in EVENT_MODES {
            let (o, wall) = run_event_point(&assets, replicas, requests, mode)?;
            let digest = o.digest();
            if mode == "minclock" {
                base_digest = digest;
            }
            println!(
                "{replicas:<9} {mode:<9} {:>10.1} {digest:>18x} {:>8} {:>12.3}",
                wall * 1e3,
                if digest == base_digest { "yes" } else { "NO" },
                o.fleet.metrics.goodput_rps(),
            );
        }
    }
    println!();
    println!(
        "### scenario sweep ({SCENARIO_SPEC}, jsq dispatch, base rate \
         {SCALING_RATE} r/s, {} requests, 4 slots/replica; class-blind fifo \
         vs class-aware preemptive slo on the same seeded trace)",
        2 * requests
    );
    println!(
        "{:<9} {:<6} {:>9} {:>13} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "replicas",
        "sched",
        "int SLO%",
        "int TTFT p99",
        "bat SLO%",
        "bat done",
        "preempt",
        "tok/s",
        "wall (s)"
    );
    for &replicas in &SCENARIO_REPLICAS {
        for policy in SCENARIO_POLICIES {
            let wall = Instant::now();
            let o = run_scenario_point(&assets, replicas, 2 * requests, policy)?;
            let m = &o.fleet.metrics;
            let int = m.per_class.get(&TenantClass::Interactive);
            let bat = m.per_class.get(&TenantClass::Batch);
            println!(
                "{replicas:<9} {:<6} {:>8.0}% {:>13.4} {:>8.0}% {:>9} {:>9} {:>9.1} {:>10.2}",
                policy.name(),
                int.map_or(0.0, |s| s.slo_attainment() * 100.0),
                int.map_or(0.0, |s| s.ttft.percentile(99.0)),
                bat.map_or(0.0, |s| s.slo_attainment() * 100.0),
                bat.map_or(0, |s| s.completed),
                m.preemptions(),
                m.throughput_tps(),
                wall.elapsed().as_secs_f64(),
            );
        }
    }
    Ok(())
}

//! Fleet-serving bench: sweep open-loop Poisson arrival rate against the
//! fleet's tail latency (p99 TTFT measured from arrival, queueing
//! included), mean TPOT, goodput, SLO attainment, and cross-session
//! expert-reuse — for each scheduling policy, serial interleaved decode
//! (`max_decode_batch = 1`) versus cross-session batched decode.  This
//! is the classic serving-paper "rate vs p99" curve, produced on the
//! co-simulated virtual timeline (deterministic under the fixed seed).
//!
//! `--json` runs a small fixed smoke configuration instead and writes
//! `BENCH_serving.json` (p50/p99 TTFT/TPOT, expert dedup ratio per
//! decode-batch setting) so CI can track the perf trajectory in a
//! machine-readable form.
//!
//! Skips politely if `make artifacts` has not been run.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use dymoe::config::{PolicyConfig, ServingConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::model::assets::ModelAssets;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess};
use dymoe::serving::policy::PolicyKind;
use dymoe::serving::{run_fleet, FleetConfig, FleetOutcome};
use dymoe::util::json::Json;
use dymoe::workload::TraceGen;

const OUT_PATH: &str = "BENCH_serving.json";

/// One deterministic fleet run (fresh engine, fixed seeds).
fn run_point(
    assets: &Arc<ModelAssets>,
    rate: f64,
    policy: PolicyKind,
    max_decode_batch: usize,
    requests: usize,
) -> anyhow::Result<FleetOutcome> {
    let m = assets.manifest.model.clone();
    let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
    let strat = Box::new(DyMoEStrategy::new(PolicyConfig::default()));
    let mut engine = Engine::new(assets, sys, strat)?;
    let mut content =
        TraceGen::new(11, m.max_seq.min(80), (m.max_cache - m.max_seq).min(12));
    let trace = ArrivalGen::generate(
        0x5EED,
        ArrivalProcess::Poisson { rate },
        &mut content,
        requests,
    )?;
    let cfg = FleetConfig {
        serving: ServingConfig { max_sessions: 8, max_decode_batch, ..Default::default() },
        policy,
    };
    run_fleet(&mut engine, trace, &cfg)
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// The `--json` smoke mode: one rate, the SLO-aware policy, serial vs
/// batched decode — small enough for CI, rich enough to track.
fn smoke_json(assets: &Arc<ModelAssets>) -> anyhow::Result<Json> {
    let requests = 12;
    let rate = 0.4;
    let mut points = Vec::new();
    for &batch in &[1usize, 8] {
        let o = run_point(assets, rate, PolicyKind::SloAware, batch, requests)?;
        let mut p = BTreeMap::new();
        p.insert("max_decode_batch".to_string(), num(batch as f64));
        p.insert("ttft_p50_s".to_string(), num(o.metrics.ttft.percentile(50.0)));
        p.insert("ttft_p99_s".to_string(), num(o.metrics.ttft.percentile(99.0)));
        p.insert("tpot_p50_s".to_string(), num(o.metrics.tpot.percentile(50.0)));
        p.insert("tpot_p99_s".to_string(), num(o.metrics.tpot.percentile(99.0)));
        p.insert("tpot_mean_s".to_string(), num(o.metrics.tpot.mean()));
        p.insert("goodput_rps".to_string(), num(o.metrics.goodput_rps()));
        p.insert("throughput_tps".to_string(), num(o.metrics.throughput_tps()));
        p.insert("mean_decode_batch".to_string(), num(o.dedup.mean_batch()));
        p.insert(
            "expert_dedup_ratio".to_string(),
            num(o.dedup.expert_reuse_ratio()),
        );
        p.insert(
            "dedup_saved_fetches".to_string(),
            num(o.dedup.saved_fetches() as f64),
        );
        points.push(Json::Obj(p));
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("model".to_string(), Json::Str("mixtral-mini".to_string()));
    root.insert("policy".to_string(), Json::Str("slo".to_string()));
    root.insert("requests_per_point".to_string(), num(requests as f64));
    root.insert("rate_rps".to_string(), num(rate));
    root.insert("skipped".to_string(), Json::Bool(false));
    root.insert("points".to_string(), Json::Arr(points));
    Ok(Json::Obj(root))
}

fn main() -> anyhow::Result<()> {
    let json_mode = std::env::args().any(|a| a == "--json");
    let Ok(assets) = ModelAssets::load("artifacts", "mixtral-mini") else {
        eprintln!("artifacts missing; run `make artifacts` first");
        if json_mode {
            // Record the skip machine-readably rather than failing CI.
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(), Json::Str("serving".to_string()));
            root.insert("skipped".to_string(), Json::Bool(true));
            std::fs::write(OUT_PATH, Json::Obj(root).to_string())?;
            println!("wrote {OUT_PATH} (skipped: no artifacts)");
        }
        return Ok(());
    };
    let assets = Arc::new(assets);

    if json_mode {
        let j = smoke_json(&assets)?;
        std::fs::write(OUT_PATH, j.to_string())?;
        println!("{}", j.to_string());
        println!("wrote {OUT_PATH}");
        return Ok(());
    }

    let requests = 16;
    let rates = [0.05, 0.1, 0.2, 0.4, 0.8];
    let batches = [1usize, 8];
    println!(
        "### bench: fleet serving (mixtral-mini, 16 GB, {requests} requests/point, \
         Poisson arrivals; decode batch 1 = serial interleaved)"
    );
    println!(
        "{:<8} {:<6} {:>6} {:>12} {:>12} {:>12} {:>12} {:>8} {:>8} {:>10}",
        "rate",
        "sched",
        "batch",
        "TTFT p50",
        "TTFT p99",
        "TPOT mean",
        "goodput r/s",
        "SLO %",
        "reuse",
        "wall (s)"
    );
    println!("{}", "-".repeat(102));
    for &rate in &rates {
        for policy in PolicyKind::ALL {
            for &batch in &batches {
                let wall = Instant::now();
                let outcome = run_point(&assets, rate, policy, batch, requests)?;
                println!(
                    "{rate:<8} {:<6} {batch:>6} {:>12.4} {:>12.4} {:>12.4} {:>12.3} \
                     {:>7.0}% {:>7.2}x {:>10.2}",
                    policy.name(),
                    outcome.metrics.ttft.percentile(50.0),
                    outcome.metrics.ttft.percentile(99.0),
                    outcome.metrics.tpot.mean(),
                    outcome.metrics.goodput_rps(),
                    outcome.metrics.slo_attainment() * 100.0,
                    outcome.dedup.expert_reuse_ratio(),
                    wall.elapsed().as_secs_f64(),
                );
            }
        }
    }
    Ok(())
}

//! End-to-end bench regenerating the Fig.-10 comparison: per-system
//! request latency (virtual TTFT/TPOT at paper scale) plus the host-side
//! wall cost of the coordinator+numerics per request.
//!
//! Skips politely if `make artifacts` has not been run.

use std::sync::Arc;
use std::time::Instant;

use dymoe::baselines::{AccelerateStatic, Fiddler, MixtralOffloading, MoeInfinity};
use dymoe::config::{LowMode, PolicyConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::{DyMoEStrategy, Strategy};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::workload::TraceGen;

fn systems(m: &dymoe::model::manifest::MiniModel) -> Vec<(&'static str, Box<dyn Strategy>)> {
    vec![
        (
            "DyMoE(4/0)",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 0.75,
                low_mode: LowMode::Skip,
                ..Default::default()
            })),
        ),
        (
            "DyMoE(4/2)",
            Box::new(DyMoEStrategy::new(PolicyConfig {
                retention: 0.75,
                low_mode: LowMode::Int2,
                ..Default::default()
            })),
        ),
        ("Accelerate(int4)", Box::new(AccelerateStatic::new(Precision::Int4))),
        (
            "MixtralOffloading(int4)",
            Box::new(MixtralOffloading::new(Precision::Int4, m.top_k)),
        ),
        (
            "MoE-Infinity(int4)",
            Box::new(MoeInfinity::new(Precision::Int4, m.n_layers, m.n_experts, m.top_k)),
        ),
        ("Fiddler(bf16)", Box::new(Fiddler)),
    ]
}

fn main() -> anyhow::Result<()> {
    let Ok(assets) = ModelAssets::load("artifacts", "mixtral-mini") else {
        eprintln!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    let assets = Arc::new(assets);
    let m = assets.manifest.model.clone();
    println!("### bench: fig10 end-to-end (mixtral-mini, 16 GB, 4 requests/system)");
    println!(
        "{:<26} {:>12} {:>12} {:>14} {:>12}",
        "system", "TTFT (s)", "TPOT (s)", "wall/req (s)", "XLA execs"
    );
    println!("{}", "-".repeat(80));
    for (name, strat) in systems(&m) {
        let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
        let mut e = Engine::new(&assets, sys, strat)?;
        let mut gen = TraceGen::new(11, 80, 12);
        let n = 4;
        let wall = Instant::now();
        let execs0 = e.exec.runtime.exec_count();
        let (mut ttft, mut tpot) = (0.0, 0.0);
        for _ in 0..n {
            let r = gen.next_request();
            let o = e.run(&r.prompt, r.max_new)?;
            ttft += o.ttft / n as f64;
            tpot += o.tpot() / n as f64;
        }
        let wall_per = wall.elapsed().as_secs_f64() / n as f64;
        let execs = (e.exec.runtime.exec_count() - execs0) / n as u64;
        println!(
            "{name:<26} {ttft:>12.4} {tpot:>12.4} {wall_per:>14.3} {execs:>12}"
        );
    }
    Ok(())
}

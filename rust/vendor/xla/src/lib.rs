//! Offline stub of the `xla` (xla_extension / PJRT) bindings used by
//! `dymoe::runtime`.
//!
//! Host-side [`Literal`] construction and conversion work for real (the
//! data is kept in a typed byte buffer), so everything up to the PJRT
//! boundary behaves normally.  Anything that would need the native XLA
//! runtime — creating a [`PjRtClient`], compiling an HLO module,
//! staging device buffers, executing — returns a clear
//! "runtime unavailable" [`Error`].
//!
//! `dymoe` fails fast with that error when an engine is constructed, and
//! its artifact-dependent tests/benches skip politely, so `cargo build`
//! and `cargo test` work on machines without the PJRT CPU plugin.  Point
//! the `xla` path dependency in `../../Cargo.toml` at the real bindings
//! to run actual numerics.

use std::fmt;

/// Error type mirroring the real bindings' (Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT runtime is not available in this offline build \
         (stub crate rust/vendor/xla; point the `xla` path dependency at the \
         real bindings to execute artifacts)"
    ))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
    impl Sealed for u32 {}
}

/// Element types a [`Literal`] can hold (the subset dymoe uses).
pub trait NativeType: Copy + sealed::Sealed {
    const TAG: &'static str;
    fn to_bytes(self) -> [u8; 4];
    fn from_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TAG: &'static str = "f32";
    fn to_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TAG: &'static str = "i32";
    fn to_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

impl NativeType for u32 {
    const TAG: &'static str = "u32";
    fn to_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_bytes(b: [u8; 4]) -> Self {
        u32::from_le_bytes(b)
    }
}

/// A host tensor: typed byte buffer + dims.  Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    tag: &'static str,
    bytes: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_bytes());
        }
        Literal { tag: T::TAG, bytes, dims: vec![data.len() as i64] }
    }

    fn element_count(&self) -> i64 {
        (self.bytes.len() / 4) as i64
    }

    /// Reinterpret the literal with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) from {} elements",
                self.element_count()
            )));
        }
        Ok(Literal { tag: self.tag, bytes: self.bytes.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tag != T::TAG {
            return Err(Error(format!("to_vec::<{}> on a {} literal", T::TAG, self.tag)));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decompose a tuple literal.  Tuples only come out of executions,
    /// which the stub cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// The literal's dims (unused by dymoe, kept for API parity).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// An addressable PJRT device (opaque).
#[derive(Debug)]
pub struct PjRtDevice(());

/// A device buffer (opaque; never constructible through the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// The PJRT client.  [`PjRtClient::cpu`] fails in the stub, so no method
/// past construction is ever reachable.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn addressable_devices(&self) -> Vec<PjRtDevice> {
        Vec::new()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (opaque).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a module proto (opaque).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled executable (opaque; never constructible through the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("offline"), "{msg}");
    }
}

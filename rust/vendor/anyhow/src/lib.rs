//! Offline stand-in for the `anyhow` crate, API-compatible with the
//! subset this repository uses:
//!
//! * [`Error`] — a message plus an optional context chain; convertible
//!   from any `std::error::Error` (so `?` works on std / vendored-crate
//!   errors inside functions returning [`Result`]).
//! * [`Result<T>`] with the `E = Error` default.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * The [`Context`] extension trait (`.context(..)` /
//!   `.with_context(|| ..)`) on `Result` and `Option`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion coherent.

use std::fmt::{self, Debug, Display};

/// An error message with an optional chain of underlying causes
/// (outermost context first, original error last).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap `self` in one more layer of context.
    fn wrap(self, context: String) -> Error {
        Error { msg: context, cause: Some(Box::new(self)) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.cause.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        let mut cause = self.cause.as_deref();
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = c.cause.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg, cause: None }
    }
}

/// `Result` with this crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion into [`Error`] for the [`Context`] impls.  Mirrors the real
/// crate's private `ext::StdError` trait: one blanket impl for real
/// errors, one concrete impl for [`Error`] itself (coherent because
/// `Error` does not implement `std::error::Error`).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Attach context to failures: `Result<T, E>` for any convertible `E`
/// (including [`Error`]), and `Option<T>` (where `None` becomes an error
/// carrying the context message).
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().wrap(context.to_string())),
        }
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().wrap(f().to_string())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let base: Result<(), std::io::Error> = Err(io_err());
        let e = base.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<u32> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert!(format!("{e:?}").contains("inner 7"));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        fn g(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(g(false).unwrap_err().to_string().contains("condition failed"));
    }
}

//! End-to-end serving driver (DESIGN.md deliverable): load the trained
//! mini-Mixtral, serve a ShareGPT-like request trace at batch size 1 on a
//! simulated 16 GB edge device, and report TTFT/TPOT for DyMoE against a
//! representative baseline — proving all three layers compose:
//! Pallas kernels (L1, in the HLO artifacts) -> JAX model pieces (L2) ->
//! Rust coordination (L3) with real numerics and virtual device time.
//!
//! ```sh
//! make artifacts && cargo run --release --example edge_serving
//! ```
//! Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;

use dymoe::baselines::MixtralOffloading;
use dymoe::config::{LowMode, PolicyConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::{DyMoEStrategy, Strategy};
use dymoe::metrics::LatencyReport;
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::util::table::Table;
use dymoe::workload::TraceGen;

fn serve(
    assets: &Arc<ModelAssets>,
    vram_gb: u64,
    strategy: Box<dyn Strategy>,
    n_requests: usize,
) -> anyhow::Result<(String, LatencyReport, f64, u64)> {
    let sys = SystemConfig::edge_preset(&assets.manifest.model.name, vram_gb)?;
    let mut engine = Engine::new(assets, sys, strategy)?;
    let m = engine.model().clone();
    let mut gen = TraceGen::new(42, m.max_seq.min(80), 16);
    let mut report = LatencyReport::default();
    let wall = std::time::Instant::now();
    let mut tokens_out = 0usize;
    for i in 0..n_requests {
        let r = gen.next_request();
        let out = engine.run(&r.prompt, r.max_new)?;
        tokens_out += out.tokens.len();
        report.record(out.ttft, out.tpot());
        if i < 3 {
            println!(
                "  req {i}: {} prompt + {} out tokens, TTFT {:.4}s TPOT {:.4}s",
                r.prompt.len(),
                out.tokens.len(),
                out.ttft,
                out.tpot()
            );
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();
    println!(
        "  ... {n_requests} requests, {tokens_out} tokens generated, host wall {wall_s:.1}s, \
         cache hit {:.2}, prefetch acc {:.2}",
        engine.cache.stats.hit_rate(),
        engine.prefetch_stats.accuracy()
    );
    Ok((
        engine.strategy.name(),
        report,
        engine.cache.stats.hit_rate(),
        engine.stats.transferred_bytes,
    ))
}

fn main() -> anyhow::Result<()> {
    let assets = Arc::new(ModelAssets::load("artifacts", "mixtral-mini")?);
    let vram = 16;
    let n = 12;
    println!(
        "== edge serving: {} @ {vram} GB (paper-scale Mixtral-8x7B device model) ==",
        assets.manifest.model.name
    );

    println!("\nDyMoE(4/0, r=0.75):");
    let dymoe = serve(
        &assets,
        vram,
        Box::new(DyMoEStrategy::new(PolicyConfig {
            retention: 0.75,
            low_mode: LowMode::Skip,
            ..Default::default()
        })),
        n,
    )?;

    println!("\nMixtral-Offloading(int4) baseline:");
    let top_k = assets.manifest.model.top_k;
    let base = serve(
        &assets,
        vram,
        Box::new(MixtralOffloading::new(Precision::Int4, top_k)),
        n,
    )?;

    let mut t = Table::new(
        "end-to-end latency (virtual seconds, paper-scale)",
        &["system", "TTFT mean", "TTFT p95", "TPOT mean", "TPOT p95", "GB moved"],
    );
    for (name, rep, _, bytes) in [&dymoe, &base] {
        let mut row = rep.summary_row(name);
        row.push(format!("{:.2}", *bytes as f64 / 1e9));
        t.row(row);
    }
    println!("\n{}", t.render());
    println!(
        "speedup: TTFT {:.2}x, TPOT {:.2}x",
        base.1.ttft.mean() / dymoe.1.ttft.mean(),
        base.1.tpot.mean() / dymoe.1.tpot.mean()
    );
    Ok(())
}

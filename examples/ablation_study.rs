//! Reproduce the paper's Table-3 ablation from the library API (the same
//! driver is available as `dymoe experiment table3`).
//!
//! ```sh
//! make artifacts && cargo run --release --example ablation_study
//! ```

use dymoe::experiments::{self, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions {
        requests: 5,
        models: vec!["mixtral-mini".into()],
        ..Default::default()
    };
    let text = experiments::run("table3", &opts)?;
    println!("{text}");
    println!("(also saved under results/table3.txt / results/table3.json)");
    Ok(())
}

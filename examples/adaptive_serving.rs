//! Load-adaptive retention (the paper's §6.3 deployment story): a bursty
//! request queue drives a proportional controller that trades retention
//! (accuracy) for latency under pressure and restores quality when idle.
//!
//! ```sh
//! make artifacts && cargo run --release --example adaptive_serving
//! ```

use std::collections::VecDeque;
use std::sync::Arc;

use dymoe::config::{LowMode, PolicyConfig, SystemConfig};
use dymoe::coordinator::adaptive::RetentionController;
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::model::assets::ModelAssets;
use dymoe::util::rng::Rng;
use dymoe::util::table::Table;
use dymoe::workload::TraceGen;

fn main() -> anyhow::Result<()> {
    let assets = Arc::new(ModelAssets::load("artifacts", "mixtral-mini")?);
    let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;
    let policy = PolicyConfig {
        retention: 0.9,
        low_mode: LowMode::Skip,
        ..Default::default()
    };
    let mut engine = Engine::new(&assets, sys, Box::new(DyMoEStrategy::new(policy)))?;
    let mut controller =
        RetentionController::new(0.55, 0.95, 6).with_tpot_slo(0.035);

    // Bursty Poisson-ish arrivals on the virtual clock: a calm phase, a
    // burst, then calm again.
    let mut gen = TraceGen::new(21, 80, 12);
    let mut rng = Rng::new(5);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut t = 0.0;
    for i in 0..24 {
        let rate = if (8..16).contains(&i) { 18.0 } else { 2.0 }; // burst
        t += rng.exponential(rate);
        arrivals.push(t);
    }

    let mut queue: VecDeque<(f64, dymoe::workload::Request)> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut table = Table::new(
        "load-adaptive retention (mixtral-mini @ 16 GB, TPOT SLO 35 ms)",
        &["req", "queue", "r chosen", "TTFT (s)", "TPOT (s)", "wait (s)"],
    );
    let mut served = 0;
    while served < arrivals.len() {
        let now = engine.timeline.gpu.free_at;
        // admit everything that has arrived by the virtual clock
        while next_arrival < arrivals.len() && arrivals[next_arrival] <= now.max(0.0) {
            queue.push_back((arrivals[next_arrival], gen.next_request()));
            next_arrival += 1;
        }
        if queue.is_empty() {
            // idle: jump the virtual clock to the next arrival
            if next_arrival < arrivals.len() {
                let gap = arrivals[next_arrival] - now;
                if gap > 0.0 {
                    engine.timeline.gpu.schedule(now, gap); // idle wait
                }
                continue;
            }
            break;
        }
        let (arrived, req) = queue.pop_front().unwrap();
        let r = controller.retention(queue.len());
        engine.strategy.set_retention(r);
        let out = engine.run(&req.prompt, req.max_new)?;
        controller.observe_tpot(out.tpot());
        served += 1;
        table.row(vec![
            format!("{served}"),
            format!("{}", queue.len()),
            format!("{r:.3}"),
            format!("{:.4}", out.ttft),
            format!("{:.4}", out.tpot()),
            format!("{:.3}", (out.start - arrived).max(0.0)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "retention throttled during the burst (rows with deep queues) and \
         recovered to {:.2} afterwards",
        controller.retention(0)
    );
    Ok(())
}

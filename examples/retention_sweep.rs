//! The paper's "tunable knob" story (§6.3): sweep the retention ratio r
//! and report the latency/accuracy trade-off — users trade a marginal
//! amount of accuracy for significant latency reduction at peak load.
//!
//! ```sh
//! make artifacts && cargo run --release --example retention_sweep -- [model]
//! ```

use std::sync::Arc;

use dymoe::config::{LowMode, PolicyConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::eval::{evaluate_suite, mean_token_acc};
use dymoe::model::assets::ModelAssets;
use dymoe::model::executor::Executor;
use dymoe::util::table::Table;
use dymoe::workload::{load_suites, TraceGen};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "mixtral-mini".into());
    let assets = Arc::new(ModelAssets::load("artifacts", &model)?);
    let exec = std::rc::Rc::new(Executor::new(assets.clone())?);
    let suites = load_suites("artifacts")?;
    let items = 12;
    let requests = 4;

    let mut t = Table::new(
        &format!("retention sweep on {model} (DyMoE 4/0 @ 16 GB)"),
        &["r", "mean token-acc", "TTFT (s)", "TPOT (s)"],
    );
    for r in [0.5, 0.625, 0.75, 0.875, 1.0] {
        let policy = PolicyConfig {
            retention: r,
            low_mode: LowMode::Skip,
            ..Default::default()
        };
        // accuracy at ample VRAM
        let mut sys_acc = SystemConfig::edge_preset(&model, 24)?;
        sys_acc.hardware.vram_bytes = 4096 * GB;
        let mut acc_engine = Engine::with_executor(
            &assets,
            sys_acc,
            Box::new(DyMoEStrategy::new(policy.clone())),
            EngineOptions {
                collect_logits: true,
                strict_precision: true,
                ..Default::default()
            },
            exec.clone(),
        )?;
        let mut scores = Vec::new();
        for suite in &suites {
            let (s, _) = evaluate_suite(&mut acc_engine, suite, items, None)?;
            scores.push(s);
        }
        let acc = mean_token_acc(&scores);

        // latency at the edge preset
        let sys = SystemConfig::edge_preset(&model, 16)?;
        let mut lat_engine = Engine::with_executor(
            &assets,
            sys,
            Box::new(DyMoEStrategy::new(policy)),
            EngineOptions::default(),
            exec.clone(),
        )?;
        let m = lat_engine.model().clone();
        let mut gen = TraceGen::new(9, m.max_seq.min(80), 12);
        let (mut ttft, mut tpot) = (0.0, 0.0);
        for _ in 0..requests {
            let req = gen.next_request();
            let o = lat_engine.run(&req.prompt, req.max_new)?;
            ttft += o.ttft / requests as f64;
            tpot += o.tpot() / requests as f64;
        }
        t.row(vec![
            format!("{r:.3}"),
            format!("{acc:.4}"),
            format!("{ttft:.4}"),
            format!("{tpot:.4}"),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

//! Quickstart: load a model's AOT artifacts, serve one request with the
//! DyMoE policy on a simulated 16 GB edge device, print the result.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use dymoe::config::{LowMode, PolicyConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::model::assets::ModelAssets;
use dymoe::workload::tokens;

fn main() -> anyhow::Result<()> {
    // 1. Load the build-time artifacts (HLO text + quantized weight store).
    let assets = Arc::new(ModelAssets::load("artifacts", "mixtral-mini")?);
    println!(
        "loaded {} ({} layers x {} experts, top-{})",
        assets.manifest.model.name,
        assets.manifest.model.n_layers,
        assets.manifest.model.n_experts,
        assets.manifest.model.top_k,
    );

    // 2. A simulated 16 GB edge device (paper-scale cost model).
    let sys = SystemConfig::edge_preset("mixtral-mini", 16)?;

    // 3. The DyMoE policy: importance-aware 4/0 dynamic quantization with
    //    depth-aware scheduling and look-ahead prefetching.
    let policy = PolicyConfig {
        retention: 0.75,
        low_mode: LowMode::Skip,
        ..Default::default()
    };
    let mut engine = Engine::new(&assets, sys, Box::new(DyMoEStrategy::new(policy)))?;

    // 4. Serve one request: a periodic pattern the model was trained on.
    let mut prompt = vec![tokens::BOS, tokens::TAG_REPEAT];
    for i in 0..24 {
        prompt.push(tokens::LETTER0 + (i % 3));
    }
    let out = engine.run(&prompt, 8)?;

    println!("prompt tokens : {:?}", &prompt);
    println!("output tokens : {:?}", out.tokens);
    println!("TTFT          : {:.4} s (virtual, paper-scale)", out.ttft);
    println!("TPOT          : {:.4} s", out.tpot());
    println!(
        "cache         : {:.1}% hit rate, {} promotions, {} conservative reuses",
        engine.cache.stats.hit_rate() * 100.0,
        engine.cache.stats.promotions,
        engine.cache.stats.conservative_reuses,
    );
    println!(
        "prefetch      : {} issued / {} useful; skipped experts: {}",
        engine.prefetch_stats.issued,
        engine.prefetch_stats.useful,
        engine.stats.skipped_experts,
    );
    Ok(())
}
